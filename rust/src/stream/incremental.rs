//! Incremental / decremental SMO over a sliding window.
//!
//! [`IncrementalSmo`] keeps an exact, feasible dual point
//! `(α, ᾱ, s = K(α−ᾱ))` for the OCSSVM dual of the *current window
//! contents* and updates it per sample instead of re-solving from
//! scratch:
//!
//! * **add** — the incoming sample's multipliers are seeded at the
//!   clipped box midpoint (`cap/2`), paid for by mass-conserving
//!   transfers from donor coordinates so Σα = 1 and Σᾱ = ε never move;
//! * **decremental remove** — the evicted sample's α/ᾱ mass is
//!   redistributed to in-window coordinates with box headroom (its γ
//!   contribution leaves the margins in the same O(m) pass). The
//!   victim is picked by the configured
//!   [`EvictionPolicy`](super::policy::EvictionPolicy)
//!   ([`PolicyKind::Fifo`] reproduces the classic oldest-first window
//!   bitwise; [`PolicyKind::InteriorFirst`] evicts the smallest
//!   |α − ᾱ| resident so support vectors stay);
//! * **targeted unlearning** — [`IncrementalSmo::forget`] removes an
//!   *arbitrary* resident sample by its stable id ("forget user X"):
//!   same mass withdrawal, then the window compacts by swap-remove and
//!   the freed mass redistributes under the *grown* boxes
//!   (cap = 1/(νm) loosens to 1/(ν(m−1)), so the mass always fits);
//! * **repair** — a bounded number of warm-started SMO sweeps
//!   ([`solve_from`]) restores KKT within `tol`. Warm-starting from the
//!   perturbed optimum is the whole trick: the perturbation touches O(1)
//!   coordinates, so repair needs a few dozen pair updates where a cold
//!   solve needs thousands (`benches/streaming.rs`).
//!
//! Every mass transfer applies its exact rank-1 margin update from the
//! window's live Gram row, so `s` stays bit-consistent with the dual
//! between repairs (a periodic O(m²) refresh caps floating-point drift
//! on unbounded streams). [`IncrementalSmo::report`] assembles the same
//! [`FitReport`] batch training returns — model, full dual, stats and
//! KKT certificate — so everything downstream of a `Trainer` works
//! unchanged on a streamed model.

use crate::error::Error;
use crate::kernel::featmap::EngineKind;
use crate::kernel::{Kernel, Precision};
use crate::solver::api::{DualSolution, FitReport};
use crate::solver::ocssvm::SlabModel;
use crate::solver::smo::{solve_from, SmoParams, WarmState};
use crate::solver::{validate, SolveStats};
use crate::Result;

use super::policy::PolicyKind;
use super::window::SlidingWindow;

/// Mass below this is considered fully placed (absolute, on multipliers
/// whose scale is 1/m).
const MASS_EPS: f64 = 1e-15;

/// Streaming solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct IncrementalConfig {
    /// hyper-parameters shared with batch SMO (ν₁, ν₂, ε, tol, …);
    /// `max_iter` is ignored — `repair_max_iter` bounds the per-update
    /// sweeps instead
    pub smo: SmoParams,
    /// iteration bound for the per-update KKT repair
    pub repair_max_iter: usize,
    /// exact O(m²) margin recomputation every this many admits (caps
    /// floating-point drift on unbounded streams)
    pub refresh_every: u64,
    /// which resident sample a full-window absorb evicts
    pub policy: PolicyKind,
    /// compute mode for **background retrains** spawned off this
    /// stream ([`Precision::F32`] = certified single-precision batch
    /// fits). The live absorb path — window Gram, margins, repairs —
    /// always runs f64 so snapshot checksums and restores stay
    /// bitwise reproducible; this knob is a compute hint, not
    /// semantic config, and is deliberately excluded from snapshot
    /// config fingerprints.
    pub precision: Precision,
    /// training engine for the stream: [`EngineKind::Exact`] runs this
    /// module's windowed SMO; `nystroem` / `rff` run the lifted
    /// feature-map engine ([`super::approx::ApproxIncremental`]) whose
    /// per-absorb and scoring cost are independent of the resident
    /// count. Part of the snapshot config fingerprint (format v3;
    /// v2 snapshots decode as `exact`).
    pub engine: EngineKind,
    /// lifted dimension D for the approx engines (landmark count for
    /// Nyström, feature count for RFF); ignored when `engine` is exact
    pub features: usize,
}

impl Default for IncrementalConfig {
    fn default() -> Self {
        IncrementalConfig {
            smo: SmoParams::default(),
            repair_max_iter: 100_000,
            refresh_every: 1024,
            policy: PolicyKind::Fifo,
            precision: Precision::F64,
            engine: EngineKind::Exact,
            features: 64,
        }
    }
}

/// Exact dual state of the current window, updated per sample.
pub struct IncrementalSmo {
    window: SlidingWindow,
    cfg: IncrementalConfig,
    alpha: Vec<f64>,
    alpha_bar: Vec<f64>,
    /// margins s = K(α − ᾱ) over the window, maintained incrementally
    s: Vec<f64>,
    rho1: f64,
    rho2: f64,
    /// stats of the most recent repair
    stats: SolveStats,
    /// cumulative repair iterations across the stream
    repair_iterations: u64,
    /// adaptive scale on `repair_max_iter` (1.0 = configured budget);
    /// set from mailbox pressure by the shard worker — transient, never
    /// persisted, and floored so repairs always make progress
    budget_frac: f64,
    /// wall micros the most recent push spent admitting the sample
    /// (Gram row + mass transfers + margin refresh), then repairing —
    /// the per-stage split the shard worker turns into Gram/Repair
    /// sub-spans ([`IncrementalSmo::last_stage_us`])
    last_admit_us: u64,
    last_repair_us: u64,
    /// Reusable warm-start buffers for [`IncrementalSmo::repair`]: the
    /// previous repair's state vectors ping-pong back as the next
    /// repair's scratch, so the steady-state absorb path allocates
    /// nothing (lint rule [[R3]]).
    scratch_alpha: Vec<f64>,
    scratch_abar: Vec<f64>,
    scratch_s: Vec<f64>,
}

impl IncrementalSmo {
    /// Empty streaming solver over a fresh window.
    pub fn new(
        kernel: Kernel,
        capacity: usize,
        dim: usize,
        cfg: IncrementalConfig,
    ) -> IncrementalSmo {
        // Grow-once: every per-slot buffer is sized to the window
        // capacity up front, so the absorb path never reallocates —
        // growth-phase pushes land in reserved space and the repair
        // ping-pong stays within retained capacity (lint rule [[R3]]).
        IncrementalSmo {
            window: SlidingWindow::new(kernel, capacity, dim),
            cfg,
            alpha: Vec::with_capacity(capacity),
            alpha_bar: Vec::with_capacity(capacity),
            s: Vec::with_capacity(capacity),
            rho1: 0.0,
            rho2: 0.0,
            stats: SolveStats::default(),
            repair_iterations: 0,
            budget_frac: 1.0,
            last_admit_us: 0,
            last_repair_us: 0,
            scratch_alpha: Vec::with_capacity(capacity),
            scratch_abar: Vec::with_capacity(capacity),
            scratch_s: Vec::with_capacity(capacity),
        }
    }

    pub fn window(&self) -> &SlidingWindow {
        &self.window
    }

    /// Reassemble a streaming solver from persisted state (snapshot
    /// restore). The caller (`stream::persist`) has already validated
    /// feasibility and shapes; this just adopts the dual point. Run
    /// [`IncrementalSmo::repair_in_place`] afterwards if the state does
    /// not certify.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn restore(
        window: SlidingWindow,
        cfg: IncrementalConfig,
        alpha: Vec<f64>,
        alpha_bar: Vec<f64>,
        s: Vec<f64>,
        rho1: f64,
        rho2: f64,
        repair_iterations: u64,
    ) -> IncrementalSmo {
        debug_assert_eq!(alpha.len(), window.len());
        debug_assert_eq!(alpha_bar.len(), window.len());
        debug_assert_eq!(s.len(), window.len());
        // same grow-once contract as `new`: scratch reserved to window
        // capacity so post-restore absorbs never reallocate
        let capacity = window.capacity();
        IncrementalSmo {
            window,
            cfg,
            alpha,
            alpha_bar,
            s,
            rho1,
            rho2,
            stats: SolveStats::default(),
            repair_iterations,
            budget_frac: 1.0,
            last_admit_us: 0,
            last_repair_us: 0,
            scratch_alpha: Vec::with_capacity(capacity),
            scratch_abar: Vec::with_capacity(capacity),
            scratch_s: Vec::with_capacity(capacity),
        }
    }

    /// The bounded warm-started KKT repair sweep, callable on a
    /// restored state (the same sweep every absorbed sample ends with).
    pub(crate) fn repair_in_place(&mut self) -> Result<()> {
        self.repair()
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    pub fn config(&self) -> &IncrementalConfig {
        &self.cfg
    }

    /// Slab offsets of the current dual point.
    pub fn rho(&self) -> (f64, f64) {
        (self.rho1, self.rho2)
    }

    /// Lower-plane multipliers α over the window (slot order).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Upper-plane multipliers ᾱ over the window (slot order).
    pub fn alpha_bar(&self) -> &[f64] {
        &self.alpha_bar
    }

    /// The incrementally maintained margins s = K(α − ᾱ).
    pub fn margins(&self) -> &[f64] {
        &self.s
    }

    /// Margins recomputed exactly from the live Gram matrix (what
    /// snapshots serialize: the restore side recomputes from the
    /// re-derived Gram and lands on bitwise-identical values).
    pub fn fresh_margins(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.margin_of_slot(i)).collect()
    }

    /// Stats of the most recent repair solve.
    pub fn last_stats(&self) -> &SolveStats {
        &self.stats
    }

    /// Cumulative repair iterations over the stream's lifetime.
    pub fn repair_iterations(&self) -> u64 {
        self.repair_iterations
    }

    /// Wall-clock split of the most recent push, `(admit_us,
    /// repair_us)`: micros spent admitting the sample (Gram row, mass
    /// transfers, periodic margin refresh) and micros spent in the
    /// warm-started KKT repair. The shard worker places these as the
    /// Gram/Repair sub-spans tiling the tail of an Absorb span; the
    /// streaming benches report their means per BENCHJSON row.
    pub fn last_stage_us(&self) -> (u64, u64) {
        (self.last_admit_us, self.last_repair_us)
    }

    fn cap_a(&self) -> f64 {
        1.0 / (self.cfg.smo.nu1 * self.len() as f64)
    }

    fn cap_b(&self) -> f64 {
        self.cfg.smo.eps / (self.cfg.smo.nu2 * self.len() as f64)
    }

    /// Exact margin of window slot `i` under the current dual, from the
    /// live Gram row: s_i = Σ_j (α_j − ᾱ_j) k(x_i, x_j).
    fn margin_of_slot(&self, i: usize) -> f64 {
        let row = self.window.row(i);
        self.alpha
            .iter()
            .zip(&self.alpha_bar)
            .zip(row)
            .map(|((a, b), k)| (a - b) * k)
            .sum()
    }

    /// Margin of an arbitrary point under the current dual (O(m·d)) —
    /// lets callers score *before* absorbing, without building a model.
    pub fn score(&self, x: &[f64]) -> f64 {
        let kernel = self.window.kernel();
        let mut s = 0.0;
        for j in 0..self.len() {
            let g = self.alpha[j] - self.alpha_bar[j];
            if g != 0.0 {
                s += g * kernel.eval(self.window.point(j), x);
            }
        }
        s
    }

    /// Absorb one sample: admit (evicting the configured policy's
    /// victim once the window is full), restore dual feasibility,
    /// repair KKT. Returns the absorbed sample's stable id (its admit
    /// sequence number — the handle [`IncrementalSmo::forget`] takes).
    /// Errors leave the pre-repair feasible state in place.
    pub fn push(&mut self, x: &[f64]) -> Result<u64> {
        let t0 = std::time::Instant::now();
        let slot = if self.window.is_full() {
            let victim = self.cfg.policy.policy().victim(
                self.window.ids(),
                &self.alpha,
                &self.alpha_bar,
            );
            // value = the evicted sample's stable id; push order is the
            // only context here, so trace/stream are left to the shard
            crate::obs::record(
                crate::obs::EventKind::Evict,
                0,
                0,
                u32::MAX,
                self.window.id(victim),
            );
            self.replace_slot(victim, x);
            victim
        } else {
            self.grow_add(x)
        };
        let id = self.window.id(slot);
        if self.window.admitted() % self.cfg.refresh_every.max(1) == 0 {
            self.recompute_margins();
        }
        self.last_admit_us = t0.elapsed().as_micros() as u64;
        let t1 = std::time::Instant::now();
        self.repair()?;
        self.last_repair_us = t1.elapsed().as_micros() as u64;
        Ok(id)
    }

    /// Targeted unlearning: remove the resident sample with stable id
    /// `id` ("forget user X"), exactly withdrawing its dual mass — the
    /// same headroom-greedy redistribution the eviction path uses, then
    /// a swap-remove compaction of the window and a warm-started
    /// bounded repair sweep. The boxes *grow* when m shrinks
    /// (cap = 1/(νm) → 1/(ν(m−1))), so the freed mass always finds
    /// headroom and Σα = 1 / Σᾱ = ε are preserved (up to the placement
    /// granularity `MASS_EPS`). A non-resident id (never admitted,
    /// evicted, or
    /// already forgotten) is a typed [`Error::Unlearning`] and the
    /// state is untouched; so is forgetting the only resident sample
    /// (an empty window has no feasible dual).
    pub fn forget(&mut self, id: u64) -> Result<()> {
        self.forget_many(std::slice::from_ref(&id))
    }

    /// Batch unlearning: remove every resident sample in `ids` with a
    /// **single** repair sweep at the end, instead of the k sequential
    /// repairs (and k intermediate hot-swapped models) that k
    /// [`IncrementalSmo::forget`] calls would cost. Each withdrawal is
    /// the same exact mass accounting as the single-sample path —
    /// withdraw while the kernel row exists, swap-remove compact,
    /// redistribute under the grown boxes — so feasibility (Σα = 1,
    /// Σᾱ = ε, box bounds) holds after every step, not just at the end.
    ///
    /// All-or-nothing validation: if any id is non-resident, duplicated
    /// in the batch, or the batch would empty the window, a typed
    /// [`Error::Unlearning`] is returned and the state is untouched.
    /// An empty batch is a no-op.
    pub fn forget_many(&mut self, ids: &[u64]) -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        // Validate the whole batch before touching any state. The error
        // is built outside the scan (lint rule [[R3]]: `forget_many` is
        // a warm fn — no allocation inside loops).
        let mut bad: Option<(u64, bool)> = None;
        for (k, &id) in ids.iter().enumerate() {
            if self.window.slot_of_id(id).is_none() {
                bad = Some((id, false));
                break;
            }
            if ids[..k].contains(&id) {
                bad = Some((id, true));
                break;
            }
        }
        if let Some((id, duplicate)) = bad {
            return Err(Error::unlearning(if duplicate {
                format!("sample id {id} appears twice in the forget batch")
            } else {
                format!(
                    "sample id {id} is not resident (never admitted, already \
                     evicted, or already forgotten)"
                )
            }));
        }
        if self.len() <= ids.len() {
            return Err(Error::unlearning(format!(
                "cannot forget all {} resident samples: an empty window has \
                 no feasible dual (close the stream instead)",
                self.len()
            )));
        }
        for &id in ids {
            // Re-resolve per iteration: earlier swap-removes remap slots.
            let slot = self
                .window
                .slot_of_id(id)
                .expect("validated resident above; batch has no duplicates");
            // Withdraw the sample's dual mass while its kernel row still
            // exists (the bumps apply the exact rank-1 margin updates).
            let freed_a = self.alpha[slot];
            let freed_b = self.alpha_bar[slot];
            self.bump_alpha(slot, -freed_a);
            self.bump_abar(slot, -freed_b);
            // Compact: the window swap-removes the slot; the dual
            // vectors mirror the identical index mapping. The remaining
            // margins are already exact — the removed γ is zero.
            self.window.remove(slot);
            self.alpha.swap_remove(slot);
            self.alpha_bar.swap_remove(slot);
            self.s.swap_remove(slot);
            // Redistribute under the grown boxes:
            // (m−1)·1/(ν(m−1)) = 1/ν ≥ 1, so the freed mass always fits
            // (ν ≤ 1).
            let rem_a = self.distribute(true, freed_a, usize::MAX);
            let rem_b = self.distribute(false, freed_b, usize::MAX);
            debug_assert!(
                rem_a <= MASS_EPS * self.len() as f64
                    && rem_b <= MASS_EPS * self.len() as f64,
                "freed mass must fit the grown boxes: {rem_a} / {rem_b} left"
            );
        }
        self.repair()
    }

    // ----------------------------------------------------- mass movement

    /// α_j += δ with the exact rank-1 margin update (γ_j moves by δ).
    fn bump_alpha(&mut self, j: usize, delta: f64) {
        self.alpha[j] += delta;
        let row = self.window.row(j);
        for (sv, rv) in self.s.iter_mut().zip(row) {
            *sv += delta * rv;
        }
    }

    /// ᾱ_j += δ with the exact rank-1 margin update (γ_j moves by −δ).
    fn bump_abar(&mut self, j: usize, delta: f64) {
        self.alpha_bar[j] += delta;
        let row = self.window.row(j);
        for (sv, rv) in self.s.iter_mut().zip(row) {
            *sv -= delta * rv;
        }
    }

    /// Hand `mass` to coordinates ≠ `skip` with box headroom, greediest
    /// headroom first. Returns whatever could not be placed (only when
    /// the rest of the box is saturated, e.g. ν = 1).
    fn distribute(&mut self, in_alpha: bool, mut mass: f64, skip: usize) -> f64 {
        let cap = if in_alpha { self.cap_a() } else { self.cap_b() };
        while mass > MASS_EPS {
            let vals = if in_alpha { &self.alpha } else { &self.alpha_bar };
            let mut best = usize::MAX;
            let mut best_room = 0.0;
            for (j, &v) in vals.iter().enumerate() {
                let room = cap - v;
                if j != skip && room > best_room {
                    best_room = room;
                    best = j;
                }
            }
            if best == usize::MAX || best_room <= MASS_EPS {
                break;
            }
            let take = mass.min(best_room);
            if in_alpha {
                self.bump_alpha(best, take);
            } else {
                self.bump_abar(best, take);
            }
            mass -= take;
        }
        mass.max(0.0)
    }

    /// Pull up to `want` mass from donor coordinates ≠ `skip`, largest
    /// donors first. Returns how much was actually collected.
    fn collect(&mut self, in_alpha: bool, want: f64, skip: usize) -> f64 {
        let mut left = want;
        while left > MASS_EPS {
            let vals = if in_alpha { &self.alpha } else { &self.alpha_bar };
            let mut best = usize::MAX;
            let mut best_val = 0.0;
            for (j, &v) in vals.iter().enumerate() {
                if j != skip && v > best_val {
                    best_val = v;
                    best = j;
                }
            }
            if best == usize::MAX || best_val <= MASS_EPS {
                break;
            }
            let take = left.min(best_val);
            if in_alpha {
                self.bump_alpha(best, -take);
            } else {
                self.bump_abar(best, -take);
            }
            left -= take;
        }
        want - left.max(0.0)
    }

    /// Seed slot `i` toward the clipped box midpoint, on top of whatever
    /// redistribution already left there (`i`'s margin contributions are
    /// applied through the usual bumps — the caller guarantees row `i`
    /// is current).
    fn seed(&mut self, in_alpha: bool, i: usize, carry: f64) {
        let cap = if in_alpha { self.cap_a() } else { self.cap_b() };
        if carry > 0.0 {
            let have = if in_alpha { self.alpha[i] } else { self.alpha_bar[i] };
            let placed = carry.min((cap - have).max(0.0));
            if placed > 0.0 {
                if in_alpha {
                    self.bump_alpha(i, placed);
                } else {
                    self.bump_abar(i, placed);
                }
            }
            // a carry the slot cannot hold goes back to the general pool
            // (sum conservation; unreachable outside ν = 1 corners)
            let overflow = carry - placed;
            if overflow > MASS_EPS {
                self.distribute(in_alpha, overflow, usize::MAX);
            }
        }
        let have = if in_alpha { self.alpha[i] } else { self.alpha_bar[i] };
        let target = cap * 0.5;
        if have < target {
            let got = self.collect(in_alpha, target - have, i);
            if got > 0.0 {
                if in_alpha {
                    self.bump_alpha(i, got);
                } else {
                    self.bump_abar(i, got);
                }
            }
        }
    }

    // ------------------------------------------------------- update paths

    /// Window still growing: append the sample, shrink every box to the
    /// new m, seed the newcomer from the clip overflow + donors.
    /// Returns the newcomer's slot.
    fn grow_add(&mut self, x: &[f64]) -> usize {
        let i = self.window.append(x);
        if self.len() == 1 {
            // the very first sample carries the whole dual mass: Σα = 1,
            // Σᾱ = ε (inside the m = 1 box since ν₁, ν₂ ≤ 1)
            let eps = self.cfg.smo.eps;
            self.alpha.push(1.0);
            self.alpha_bar.push(eps);
            self.s.push((1.0 - eps) * self.window.row(0)[0]);
            return i;
        }
        self.alpha.push(0.0);
        self.alpha_bar.push(0.0);
        // newcomer's margin under the current γ (its own γ is 0)
        let si = self.margin_of_slot(i);
        self.s.push(si);
        // caps shrank from 1/(ν(m−1)) to 1/(νm): clip the overflow into a
        // pool, then let the pool flow to whoever has headroom (usually
        // the newcomer — its box is empty)
        for in_alpha in [true, false] {
            let cap = if in_alpha { self.cap_a() } else { self.cap_b() };
            // clip by index so the sweep needs no overflow list — this
            // runs on every pre-steady-state absorb (lint rule [[R3]])
            let mut pool = 0.0;
            for j in 0..self.len() {
                let v = if in_alpha { self.alpha[j] } else { self.alpha_bar[j] };
                if v > cap {
                    if in_alpha {
                        self.bump_alpha(j, -(v - cap));
                    } else {
                        self.bump_abar(j, -(v - cap));
                    }
                    pool += v - cap;
                }
            }
            let rem = self.distribute(in_alpha, pool, usize::MAX);
            self.seed(in_alpha, i, rem);
        }
        i
    }

    /// Steady state: decrementally remove the victim slot (mass
    /// redistributed, γ contribution withdrawn from the margins), then
    /// admit the new sample in its slot and seed it. With the Fifo
    /// policy the victim is the oldest resident — bit-for-bit the
    /// pre-policy eviction path.
    fn replace_slot(&mut self, i: usize, x: &[f64]) {
        // withdraw the evicted dual mass while its kernel row still exists
        let freed_a = self.alpha[i];
        let freed_b = self.alpha_bar[i];
        self.bump_alpha(i, -freed_a);
        self.bump_abar(i, -freed_b);
        let rem_a = self.distribute(true, freed_a, i);
        let rem_b = self.distribute(false, freed_b, i);
        // swap the sample; the old kernel row is overwritten here
        self.window.replace(i, x);
        // s[i] tracked stale old-row contributions — rebuild it exactly
        self.s[i] = self.margin_of_slot(i);
        // seed the newcomer (plus any mass the saturated box bounced back)
        self.seed(true, i, rem_a);
        self.seed(false, i, rem_b);
    }

    /// Exact O(m²) margin rebuild from the live Gram matrix.
    fn recompute_margins(&mut self) {
        for i in 0..self.len() {
            self.s[i] = self.margin_of_slot(i);
        }
    }

    /// `repair_max_iter` scaled by the adaptive budget fraction. The
    /// floor (1024 iterations, but never above the configured budget)
    /// keeps a saturated stream's repairs convergent — pressure slows
    /// freshness, it must not turn repairs into `NoConvergence` drops.
    fn effective_repair_budget(&self) -> usize {
        let scaled =
            (self.cfg.repair_max_iter as f64 * self.budget_frac) as usize;
        scaled.max(1024).min(self.cfg.repair_max_iter.max(1))
    }

    /// Scale the per-repair iteration budget (see
    /// [`IncrementalSmo::effective_repair_budget`]). Transient — not
    /// persisted and not part of the snapshot config fingerprint.
    /// Clamped to `[0.25, 1.0]`; `1.0` restores `repair_max_iter`
    /// exactly, so the unloaded path is bitwise unchanged.
    pub fn set_repair_budget_frac(&mut self, frac: f64) {
        self.budget_frac =
            if frac.is_finite() { frac.clamp(0.25, 1.0) } else { 1.0 };
    }

    /// Bounded warm-started SMO sweeps restoring KKT within `tol`.
    fn repair(&mut self) -> Result<()> {
        let p = SmoParams {
            max_iter: self.effective_repair_budget(),
            ..self.cfg.smo
        };
        // Warm-start from a copy staged in the reusable scratch buffers
        // (clear + extend within retained capacity — the steady-state
        // absorb path allocates nothing, lint rule [[R3]]); an error
        // from the bounded solve leaves the pre-repair feasible state
        // in `self` untouched.
        self.scratch_alpha.clear();
        self.scratch_alpha.extend_from_slice(&self.alpha);
        self.scratch_abar.clear();
        self.scratch_abar.extend_from_slice(&self.alpha_bar);
        self.scratch_s.clear();
        self.scratch_s.extend_from_slice(&self.s);
        let warm = WarmState {
            alpha: std::mem::take(&mut self.scratch_alpha),
            alpha_bar: std::mem::take(&mut self.scratch_abar),
            s: std::mem::take(&mut self.scratch_s),
        };
        let out = solve_from(&mut self.window, &p, Some(warm))?;
        // ping-pong: the superseded state vectors become the next
        // repair's scratch, keeping both sets of buffers at capacity
        self.scratch_alpha = std::mem::replace(&mut self.alpha, out.alpha);
        self.scratch_abar =
            std::mem::replace(&mut self.alpha_bar, out.alpha_bar);
        self.scratch_s = std::mem::replace(&mut self.s, out.s);
        self.rho1 = out.rho1;
        self.rho2 = out.rho2;
        self.repair_iterations += out.stats.iterations as u64;
        self.stats = out.stats;
        Ok(())
    }

    // ------------------------------------------------------------ output

    /// The current model alone — the per-sample publish path. Gathers
    /// support rows straight off the window; no dual clones, no window
    /// matrix copy, no certificate (use [`IncrementalSmo::report`] when
    /// those are wanted).
    pub fn model(&self) -> SlabModel {
        let sv_tol = self.cfg.smo.sv_tol;
        let dim = self.window.dim();
        let sv: Vec<(usize, f64)> = self
            .alpha
            .iter()
            .zip(&self.alpha_bar)
            .map(|(a, b)| a - b)
            .enumerate()
            .filter(|(_, g)| g.abs() > sv_tol)
            .collect();
        let mut x_sv = crate::linalg::Matrix::zeros(sv.len(), dim);
        let mut gamma = Vec::with_capacity(sv.len());
        for (r, &(i, g)) in sv.iter().enumerate() {
            x_sv.row_mut(r).copy_from_slice(self.window.point(i));
            gamma.push(g);
        }
        SlabModel {
            x_sv,
            gamma,
            rho1: self.rho1,
            rho2: self.rho2,
            kernel: self.window.kernel(),
            featmap: None,
        }
    }

    /// Assemble the uniform [`FitReport`] for the current window — same
    /// shape batch [`crate::solver::Trainer::fit`] returns, certificate
    /// included.
    pub fn report(&self) -> FitReport {
        let p = &self.cfg.smo;
        let gamma: Vec<f64> = self
            .alpha
            .iter()
            .zip(&self.alpha_bar)
            .map(|(a, b)| a - b)
            .collect();
        let cls_tol = self.cap_a().min(self.cap_b()) * 1e-6;
        let certificate = validate::report_with_margins(
            &self.alpha,
            &self.alpha_bar,
            &self.s,
            self.rho1,
            self.rho2,
            p.nu1,
            p.nu2,
            p.eps,
            cls_tol,
        );
        let model = self.model();
        let mut stats = self.stats;
        stats.objective =
            0.5 * gamma.iter().zip(&self.s).map(|(g, si)| g * si).sum::<f64>();
        FitReport {
            model,
            dual: DualSolution {
                alpha: self.alpha.clone(),
                alpha_bar: self.alpha_bar.clone(),
                gamma,
                s: self.s.clone(),
                rho1: self.rho1,
                rho2: self.rho2,
            },
            stats,
            certificate,
            cascade: None,
            // the live streaming dual is always maintained in f64
            // (cfg.precision only accelerates background retrains)
            precision: Precision::F64,
            fell_back: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::solver::{SolverKind, Trainer};

    fn stream_points(n: usize, seed: u64) -> Vec<[f64; 2]> {
        let ds = SlabConfig::default().generate(n, seed);
        (0..n).map(|i| [ds.x.get(i, 0), ds.x.get(i, 1)]).collect()
    }

    fn assert_invariants(inc: &IncrementalSmo) {
        let m = inc.len();
        let p = &inc.cfg.smo;
        let (cap_a, cap_b) = (inc.cap_a(), inc.cap_b());
        let sa: f64 = inc.alpha.iter().sum();
        let sb: f64 = inc.alpha_bar.iter().sum();
        assert!((sa - 1.0).abs() < 1e-9, "sum(alpha)={sa}");
        assert!((sb - p.eps).abs() < 1e-9, "sum(alpha_bar)={sb}");
        for j in 0..m {
            assert!(
                inc.alpha[j] >= -1e-12 && inc.alpha[j] <= cap_a + 1e-12,
                "alpha[{j}]={} out of [0,{cap_a}]",
                inc.alpha[j]
            );
            assert!(
                inc.alpha_bar[j] >= -1e-12 && inc.alpha_bar[j] <= cap_b + 1e-12,
                "alpha_bar[{j}]={} out of [0,{cap_b}]",
                inc.alpha_bar[j]
            );
        }
        // margins must equal K gamma exactly (within fp accumulation)
        for i in 0..m {
            let si: f64 = (0..m)
                .map(|j| {
                    (inc.alpha[j] - inc.alpha_bar[j]) * inc.window.row(i)[j]
                })
                .sum();
            assert!(
                (si - inc.s[i]).abs() < 1e-7 * (1.0 + si.abs()),
                "margin drift at {i}: {si} vs {}",
                inc.s[i]
            );
        }
    }

    #[test]
    fn invariants_hold_through_growth_and_replacement() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.05 }] {
            let mut inc =
                IncrementalSmo::new(kernel, 60, 2, IncrementalConfig::default());
            for p in stream_points(150, 31) {
                inc.push(&p).unwrap();
            }
            assert_eq!(inc.len(), 60);
            assert_invariants(&inc);
            let report = inc.report();
            assert!(report.certificate.sum_alpha_violation < 1e-6);
            assert!(report.certificate.sum_alpha_bar_violation < 1e-6);
            assert!(report.model.width() > 0.0);
        }
    }

    #[test]
    fn streamed_dual_matches_batch_fit_on_same_window() {
        let mut inc = IncrementalSmo::new(
            Kernel::Linear,
            80,
            2,
            IncrementalConfig::default(),
        );
        for p in stream_points(120, 32) {
            inc.push(&p).unwrap();
        }
        let streamed = inc.report();
        let batch = Trainer::from_smo_params(inc.cfg.smo)
            .solver(SolverKind::Smo)
            .kernel(Kernel::Linear)
            .fit(&inc.window().matrix())
            .unwrap();
        let rel = (streamed.stats.objective - batch.stats.objective).abs()
            / batch.stats.objective.abs().max(1e-9);
        assert!(
            rel < 1e-3,
            "objective diverged: streamed {} vs batch {}",
            streamed.stats.objective,
            batch.stats.objective
        );
        let width = batch.model.width().max(1e-9);
        assert!((streamed.dual.rho1 - batch.dual.rho1).abs() / width < 1e-3);
        assert!((streamed.dual.rho2 - batch.dual.rho2).abs() / width < 1e-3);
    }

    #[test]
    fn repair_is_cheap_next_to_cold_solve() {
        let mut inc = IncrementalSmo::new(
            Kernel::Linear,
            100,
            2,
            IncrementalConfig::default(),
        );
        let pts = stream_points(130, 33);
        for p in &pts[..100] {
            inc.push(p).unwrap();
        }
        let mut repair_iters = Vec::new();
        for p in &pts[100..] {
            inc.push(p).unwrap();
            repair_iters.push(inc.last_stats().iterations);
        }
        let cold = Trainer::from_smo_params(inc.cfg.smo)
            .kernel(Kernel::Linear)
            .fit(&inc.window().matrix())
            .unwrap();
        let median_repair = {
            let mut v = repair_iters.clone();
            v.sort_unstable();
            v[v.len() / 2]
        };
        assert!(
            median_repair * 3 < cold.stats.iterations.max(1),
            "repair {median_repair} iters vs cold {}",
            cold.stats.iterations
        );
    }

    #[test]
    fn score_matches_report_model() {
        let mut inc = IncrementalSmo::new(
            Kernel::Rbf { g: 0.1 },
            40,
            2,
            IncrementalConfig::default(),
        );
        for p in stream_points(55, 34) {
            inc.push(&p).unwrap();
        }
        let model = inc.report().model;
        let probe = [19.0, 4.0];
        assert!((inc.score(&probe) - model.score(&probe)).abs() < 1e-9);
    }

    #[test]
    fn periodic_refresh_keeps_margins_exact() {
        let cfg = IncrementalConfig { refresh_every: 16, ..Default::default() };
        let mut inc = IncrementalSmo::new(Kernel::Linear, 30, 2, cfg);
        for p in stream_points(90, 35) {
            inc.push(&p).unwrap();
        }
        assert_invariants(&inc);
    }

    #[test]
    fn push_returns_the_stable_sample_id() {
        let mut inc =
            IncrementalSmo::new(Kernel::Linear, 4, 2, IncrementalConfig::default());
        for (i, p) in stream_points(7, 36).iter().enumerate() {
            assert_eq!(inc.push(p).unwrap(), i as u64);
        }
        // window holds the last 4: ids 3..=6
        assert_eq!(inc.window().slot_of_id(2), None);
        assert!(inc.window().slot_of_id(3).is_some());
    }

    #[test]
    fn forget_withdraws_mass_exactly_and_stays_feasible() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.05 }] {
            let mut inc =
                IncrementalSmo::new(kernel, 40, 2, IncrementalConfig::default());
            for p in stream_points(55, 37) {
                inc.push(&p).unwrap();
            }
            let victim = inc.window().id(7);
            inc.forget(victim).unwrap();
            assert_eq!(inc.len(), 39);
            assert_eq!(inc.window().slot_of_id(victim), None);
            assert_invariants(&inc);
            // forgetting again is a typed error, state untouched
            let alpha_before = inc.alpha().to_vec();
            let err = inc.forget(victim).unwrap_err();
            assert!(
                matches!(err, crate::Error::Unlearning(_)),
                "want Error::Unlearning, got {err:?}"
            );
            assert_eq!(inc.alpha(), &alpha_before[..]);
        }
    }

    #[test]
    fn forget_many_matches_sequential_forgets_with_one_repair() {
        let mk = || {
            let mut inc = IncrementalSmo::new(
                Kernel::Rbf { g: 0.05 },
                40,
                2,
                IncrementalConfig::default(),
            );
            for p in stream_points(55, 41) {
                inc.push(&p).unwrap();
            }
            inc
        };
        let victims: Vec<u64> = {
            let inc = mk();
            [3usize, 11, 26].iter().map(|&s| inc.window().id(s)).collect()
        };
        // batch path: one repair for the whole batch
        let mut batch = mk();
        let repairs_before = batch.repair_iterations();
        batch.forget_many(&victims).unwrap();
        assert_eq!(batch.len(), 37);
        for &id in &victims {
            assert_eq!(batch.window().slot_of_id(id), None);
        }
        assert_invariants(&batch);
        assert!(batch.repair_iterations() >= repairs_before);
        // sequential path lands on the same resident id set and a
        // feasible dual of the same problem
        let mut seq = mk();
        for &id in &victims {
            seq.forget(id).unwrap();
        }
        assert_invariants(&seq);
        let mut batch_ids = batch.window().ids().to_vec();
        let mut seq_ids = seq.window().ids().to_vec();
        batch_ids.sort_unstable();
        seq_ids.sort_unstable();
        assert_eq!(batch_ids, seq_ids);
    }

    #[test]
    fn forget_many_validates_all_before_mutating() {
        let mut inc = IncrementalSmo::new(
            Kernel::Linear,
            20,
            2,
            IncrementalConfig::default(),
        );
        for p in stream_points(20, 42) {
            inc.push(&p).unwrap();
        }
        let good = inc.window().id(4);
        let alpha_before = inc.alpha().to_vec();
        // one bad id poisons the whole batch, state untouched
        let err = inc.forget_many(&[good, 9999]).unwrap_err();
        assert!(matches!(err, crate::Error::Unlearning(_)), "{err:?}");
        assert_eq!(inc.alpha(), &alpha_before[..]);
        assert_eq!(inc.len(), 20);
        // duplicates are rejected up front too
        let err = inc.forget_many(&[good, good]).unwrap_err();
        assert!(matches!(err, crate::Error::Unlearning(_)), "{err:?}");
        assert_eq!(inc.len(), 20);
        // forgetting everything is rejected
        let all: Vec<u64> = inc.window().ids().to_vec();
        let err = inc.forget_many(&all).unwrap_err();
        assert!(matches!(err, crate::Error::Unlearning(_)), "{err:?}");
        assert_eq!(inc.len(), 20);
        // empty batch is a no-op
        inc.forget_many(&[]).unwrap();
        assert_eq!(inc.len(), 20);
    }

    #[test]
    fn forget_of_last_resident_is_rejected() {
        let mut inc =
            IncrementalSmo::new(Kernel::Linear, 4, 2, IncrementalConfig::default());
        inc.push(&[20.0, 3.0]).unwrap();
        let err = inc.forget(0).unwrap_err();
        assert!(matches!(err, crate::Error::Unlearning(_)), "{err:?}");
        assert_eq!(inc.len(), 1);
    }

    #[test]
    fn interior_first_evicts_smallest_margin_slack() {
        use crate::stream::policy::PolicyKind;
        let cfg = IncrementalConfig {
            policy: PolicyKind::InteriorFirst,
            ..Default::default()
        };
        let mut inc = IncrementalSmo::new(Kernel::Linear, 20, 2, cfg);
        let pts = stream_points(30, 38);
        for p in &pts[..20] {
            inc.push(p).unwrap();
        }
        for p in &pts[20..] {
            // the predicted victim is the smallest-|γ| (oldest-tied) slot
            let want = PolicyKind::InteriorFirst.policy().victim(
                inc.window().ids(),
                inc.alpha(),
                inc.alpha_bar(),
            );
            let want_id = inc.window().id(want);
            inc.push(p).unwrap();
            assert_eq!(
                inc.window().slot_of_id(want_id),
                None,
                "victim id {want_id} must have been evicted"
            );
            assert_invariants(&inc);
        }
    }

    #[test]
    fn fifo_policy_evicts_in_ring_order() {
        // the Fifo policy must reproduce the classic oldest-first window:
        // victims come out in admit order, one per steady-state push
        let mut inc =
            IncrementalSmo::new(Kernel::Linear, 8, 2, IncrementalConfig::default());
        let pts = stream_points(24, 39);
        for p in &pts[..8] {
            inc.push(p).unwrap();
        }
        for (k, p) in pts[8..].iter().enumerate() {
            inc.push(p).unwrap();
            assert_eq!(
                inc.window().slot_of_id(k as u64),
                None,
                "push {k}: oldest id {k} must be evicted first"
            );
            assert!(inc.window().slot_of_id(k as u64 + 1).is_some());
        }
    }
}
