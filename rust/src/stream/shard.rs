//! One shard of the multi-stream session manager: a bounded mailbox of
//! per-stream sample queues plus the worker loop that exclusively owns
//! this shard's [`StreamSession`]s.
//!
//! Concurrency shape: producers only touch the [`Mailbox`] (enqueue a
//! sample, block when the shard is at capacity); the single worker
//! thread pops batches under the same lock but **absorbs them with the
//! lock released**, so a millisecond-scale SMO repair never blocks
//! producers on other streams of the same shard. Sessions live in
//! worker-local state — no lock is ever held across an absorb.
//!
//! Fairness: the data plane is popped weighted-round-robin
//! ([`Mailbox::pop_fair`]): each scheduler visit takes at most `weight`
//! samples from one stream before the cursor moves on, so a hot stream
//! with a deep queue cannot starve its shard-mates — it just queues
//! deeper and, past its own per-stream queue bound, backpressures its
//! own producer (the bound is per stream precisely so a hot tenant's
//! backlog never blocks a shard-mate's producer).
//!
//! Retrain hand-back: a drift-escalated background retrain is submitted
//! by the shard worker and its completion is reconciled by the *owning
//! shard* on a later loop tick ([`reconcile_retrain`]) — not by whatever
//! caller thread happens to push next, as the single-writer
//! `Coordinator::stream_push` path does.
//!
//! Checkpointing: with a [`CheckpointConfig`] the worker serializes at
//! most ONE dirty session per loop tick (whichever has gone longest
//! past the cadence), so the absorb hot path is never blocked longer
//! than a single serialize; the bytes go to the manager's writer thread
//! which does the atomic temp-file + fsync + rename I/O off the data
//! plane. Close and drain write a final checkpoint so a graceful stop
//! persists the freshest state.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::sync::{Condvar, Mutex};

use crate::coordinator::{
    JobStatus, ModelRegistry, ServiceStats, TrainQueue, TrainRequest,
};
use crate::error::Error;
use crate::obs::{self, EventKind, Stage};
use crate::Result;

use super::manager::{ForgetOutcome, StreamSummary};
use super::persist::{snapshot_path, CheckpointConfig, Snapshot};
use super::session::{StreamConfig, StreamSession};

/// Control-plane events. Not subject to the data-plane bound — an open
/// or close must never be refused because samples are queued.
pub(crate) enum Control {
    Open {
        name: String,
        cfg: StreamConfig,
        weight: u32,
    },
    /// Adopt a restored session (snapshot restore). The worker inserts
    /// it, re-publishes its model (resuming the registry version
    /// sequence at `last_version + 1` or later) and acks the published
    /// version so the restorer can report deterministic state.
    Adopt {
        name: String,
        session: Box<StreamSession>,
        last_version: u64,
        ack: Sender<Option<u64>>,
    },
    Close {
        name: String,
        ack: Sender<Result<StreamSummary>>,
    },
    /// Targeted unlearning: the owning shard removes the resident
    /// sample, repairs and re-publishes, then acks — the same
    /// owning-shard reconciliation discipline retrain completions use.
    /// A bad id is a typed error in the ack, never a worker panic.
    Forget {
        name: String,
        ids: Vec<u64>,
        ack: Sender<Result<ForgetOutcome>>,
    },
    /// Front-door snapshot sweep: serialize every session this shard
    /// owns into `dir`, one result per stream (failure isolation — one
    /// bad write never blocks the rest).
    Snapshot {
        dir: PathBuf,
        ack: Sender<Vec<(String, Result<()>)>>,
    },
}

/// Where periodic checkpoints go: cadence + the writer thread's inbox.
#[derive(Clone)]
pub(crate) struct CheckpointSink {
    pub(crate) cfg: CheckpointConfig,
    pub(crate) tx: Sender<(PathBuf, Vec<u8>)>,
}

/// One mailbox sample plus the tracing context that rides with it: the
/// trace id minted at `Coordinator::push` and the enqueue timestamp the
/// Queue span starts on (both 0 while the recorder is disabled, so the
/// untraced payload costs two extra words and nothing else).
pub(crate) struct QueuedSample {
    x: Vec<f64>,
    trace: u64,
    t_enq_us: u64,
}

/// Per-stream FIFO of samples waiting to be absorbed.
struct StreamQueue {
    samples: VecDeque<QueuedSample>,
    /// weighted-fair service weight: samples per scheduler visit (≥ 1)
    weight: u32,
    /// expected sample dimension — validated at push time so a
    /// malformed producer errors instead of panicking the shard worker
    dim: usize,
}

/// Shared producer/worker state of one shard.
struct Mailbox {
    /// entry exists exactly while the stream is open on this shard
    queues: HashMap<String, StreamQueue>,
    /// round-robin service order (open order) + next-visit cursor
    order: Vec<String>,
    cursor: usize,
    /// total samples across all queues (idle/quiesce accounting; the
    /// backpressure bound is per-stream queue depth, not this total)
    queued: usize,
    /// samples popped by the worker but not yet absorbed (so "idle"
    /// means queued + in_flight == 0, not just an empty queue)
    in_flight: usize,
    control: VecDeque<Control>,
    draining: bool,
}

impl Mailbox {
    fn new() -> Mailbox {
        Mailbox {
            queues: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            queued: 0,
            in_flight: 0,
            control: VecDeque::new(),
            draining: false,
        }
    }

    /// Weighted-fair pop: scan streams round-robin from the cursor; the
    /// first non-empty queue yields up to `weight` samples and the
    /// cursor moves just past it, so every non-empty shard-mate is
    /// visited before this stream is served again. The third element is
    /// the stream's *remaining* backlog after the drain — the pressure
    /// signal the worker turns into an adaptive repair budget.
    fn pop_fair(&mut self) -> Option<(String, Vec<QueuedSample>, usize)> {
        let n = self.order.len();
        if n == 0 {
            return None;
        }
        for step in 0..n {
            let idx = (self.cursor + step) % n;
            // probe without allocating; clone only the selected name
            let Some(candidate) = self.order.get(idx) else { continue };
            let has_work = self
                .queues
                .get(candidate)
                .is_some_and(|q| !q.samples.is_empty());
            if !has_work {
                continue;
            }
            let name = candidate.clone();
            let Some(q) = self.queues.get_mut(&name) else { continue };
            let take = (q.weight.max(1) as usize).min(q.samples.len());
            let batch: Vec<QueuedSample> = q.samples.drain(..take).collect();
            let backlog = q.samples.len();
            self.queued -= take;
            self.in_flight += take;
            self.cursor = (idx + 1) % n;
            return Some((name, batch, backlog));
        }
        None
    }

    /// Drop a stream's queue and service-order slot (close finalize).
    fn remove_stream(&mut self, name: &str) {
        if let Some(q) = self.queues.remove(name) {
            self.queued -= q.samples.len();
        }
        if let Some(pos) = self.order.iter().position(|n| n == name) {
            self.order.remove(pos);
            if pos < self.cursor {
                self.cursor -= 1;
            }
            if self.order.is_empty() {
                self.cursor = 0;
            } else {
                self.cursor %= self.order.len();
            }
        }
    }
}

/// One shard: mailbox + condvars. The worker thread is spawned by the
/// manager and runs [`run_worker`] over this state.
pub(crate) struct Shard {
    mail: Mutex<Mailbox>,
    /// worker wakeups: data or control arrived, or draining began
    not_empty: Condvar,
    /// producer + quiescer wakeups: space freed / work retired
    space: Condvar,
    cap: usize,
    /// position in the manager's shard array — stamped on every event
    /// and span this shard records
    idx: u32,
}

impl Shard {
    pub(crate) fn new(idx: usize, mailbox_cap: usize) -> Shard {
        Shard {
            mail: Mutex::new("shard.mail", Mailbox::new()),
            not_empty: Condvar::new(),
            space: Condvar::new(),
            cap: mailbox_cap.max(1),
            idx: idx as u32,
        }
    }

    /// Register a stream: queue entry (so pushes routed here are valid
    /// immediately) + the Open control the worker turns into a session.
    /// Returns false when the shard is already draining.
    pub(crate) fn open(&self, name: &str, cfg: StreamConfig, weight: u32) -> bool {
        let mut mail = self.mail.lock();
        if mail.draining {
            return false;
        }
        mail.queues.insert(
            name.to_string(),
            StreamQueue {
                samples: VecDeque::new(),
                weight: weight.max(1),
                dim: cfg.dim,
            },
        );
        mail.order.push(name.to_string());
        mail.control.push_back(Control::Open {
            name: name.to_string(),
            cfg,
            weight: weight.max(1),
        });
        drop(mail);
        self.not_empty.notify_one();
        true
    }

    /// Register a restored session (queue entry + Adopt control), then
    /// block until the worker has inserted it and re-published its
    /// model. Returns the published registry version (None while the
    /// restored session was still warming up), or an error when the
    /// shard is draining / its worker already exited.
    pub(crate) fn adopt(
        &self,
        name: &str,
        session: Box<StreamSession>,
        weight: u32,
        last_version: u64,
    ) -> Result<Option<u64>> {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut mail = self.mail.lock();
            if mail.draining {
                return Err(Error::Coordinator(format!(
                    "stream '{name}': manager is shutting down"
                )));
            }
            mail.queues.insert(
                name.to_string(),
                StreamQueue {
                    samples: VecDeque::new(),
                    weight: weight.max(1),
                    dim: session.config().dim,
                },
            );
            mail.order.push(name.to_string());
            mail.control.push_back(Control::Adopt {
                name: name.to_string(),
                session,
                last_version,
                ack: tx,
            });
        }
        self.not_empty.notify_one();
        rx.recv().map_err(|_| {
            Error::Coordinator("stream manager worker exited".into())
        })
    }

    /// Ask the worker to serialize every session it owns into `dir`
    /// (one result per stream). Blocks until the sweep completes.
    pub(crate) fn snapshot_all(
        &self,
        dir: PathBuf,
    ) -> Result<Vec<(String, Result<()>)>> {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut mail = self.mail.lock();
            mail.control.push_back(Control::Snapshot { dir, ack: tx });
        }
        self.not_empty.notify_one();
        rx.recv().map_err(|_| {
            Error::Coordinator("stream manager worker exited".into())
        })
    }

    /// Enqueue one sample. The bound is **per stream**: a producer
    /// blocks only while its own stream's queue is at capacity (counted
    /// in `stats.stream_backpressure`) rather than dropping the sample,
    /// so absorbs are never lost to backpressure — and a hot tenant
    /// backpressures its own producer, not its shard-mates'.
    pub(crate) fn push(
        &self,
        name: &str,
        x: &[f64],
        trace: u64,
        t_enq_us: u64,
        stats: &ServiceStats,
    ) -> Result<()> {
        self.push_with(name, x, trace, t_enq_us, stats, true)
    }

    /// Non-blocking enqueue: a stream queue already at capacity is a
    /// typed [`Error::Saturated`] (carrying the observed depth) instead
    /// of a condvar wait — the serving layer's 429 admission path. Same
    /// mailbox implementation as the blocking [`Shard::push`]; only the
    /// at-capacity branch differs.
    pub(crate) fn try_push(
        &self,
        name: &str,
        x: &[f64],
        trace: u64,
        t_enq_us: u64,
        stats: &ServiceStats,
    ) -> Result<()> {
        self.push_with(name, x, trace, t_enq_us, stats, false)
    }

    fn push_with(
        &self,
        name: &str,
        x: &[f64],
        trace: u64,
        t_enq_us: u64,
        stats: &ServiceStats,
        block: bool,
    ) -> Result<()> {
        let mut mail = self.mail.lock();
        loop {
            if mail.draining {
                return Err(Error::Coordinator(format!(
                    "stream '{name}': manager is shutting down"
                )));
            }
            let depth = match mail.queues.get(name) {
                None => {
                    return Err(Error::Coordinator(format!(
                        "unknown stream '{name}'"
                    )))
                }
                Some(q) if q.dim != x.len() => {
                    return Err(Error::Coordinator(format!(
                        "stream '{name}': sample has {} features, \
                         stream expects {}",
                        x.len(),
                        q.dim
                    )))
                }
                Some(q) => q.samples.len(),
            };
            if depth < self.cap {
                break;
            }
            if !block {
                if trace != 0 {
                    obs::record(
                        EventKind::MailboxBlocked,
                        trace,
                        obs::stream_id(name),
                        self.idx,
                        depth as u64,
                    );
                }
                return Err(Error::Saturated { depth });
            }
            stats.stream_backpressure.inc();
            if trace != 0 {
                // one event per 50ms wait slice: value = queue depth
                obs::record(
                    EventKind::MailboxBlocked,
                    trace,
                    obs::stream_id(name),
                    self.idx,
                    depth as u64,
                );
            }
            let (guard, _) =
                self.space.wait_timeout(mail, Duration::from_millis(50));
            mail = guard;
        }
        // the guard was held since the existence check above, so the
        // entry is still there; a miss is a typed error regardless
        let Some(q) = mail.queues.get_mut(name) else {
            return Err(Error::Coordinator(format!("unknown stream '{name}'")));
        };
        q.samples.push_back(QueuedSample { x: x.to_vec(), trace, t_enq_us });
        mail.queued += 1;
        drop(mail);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Ask the worker to forget a batch of resident samples of `name`
    /// in one shard tick (single repair, single re-publish). Blocks
    /// until the owning shard has applied (or rejected) the removal.
    pub(crate) fn forget_many(
        &self,
        name: &str,
        ids: &[u64],
    ) -> Result<ForgetOutcome> {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut mail = self.mail.lock();
            if mail.draining {
                return Err(Error::Coordinator(format!(
                    "stream '{name}': manager is shutting down"
                )));
            }
            mail.control.push_back(Control::Forget {
                name: name.to_string(),
                ids: ids.to_vec(),
                ack: tx,
            });
        }
        self.not_empty.notify_one();
        rx.recv().map_err(|_| {
            Error::Coordinator("stream manager worker exited".into())
        })?
    }

    /// Request close + drain: the worker absorbs everything still queued
    /// for the stream, then answers with its final [`StreamSummary`].
    pub(crate) fn close(&self, name: &str) -> Result<StreamSummary> {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut mail = self.mail.lock();
            if mail.draining {
                return Err(Error::Coordinator(format!(
                    "stream '{name}': manager is shutting down"
                )));
            }
            mail.control.push_back(Control::Close {
                name: name.to_string(),
                ack: tx,
            });
        }
        self.not_empty.notify_one();
        rx.recv().map_err(|_| {
            Error::Coordinator("stream manager worker exited".into())
        })?
    }

    /// Block until nothing is queued or in flight on this shard.
    pub(crate) fn wait_idle(&self) {
        let mut mail = self.mail.lock();
        while mail.queued + mail.in_flight > 0 || !mail.control.is_empty() {
            let (guard, _) =
                self.space.wait_timeout(mail, Duration::from_millis(20));
            mail = guard;
        }
    }

    /// Samples currently queued (diagnostics).
    pub(crate) fn queue_depth(&self) -> usize {
        let mail = self.mail.lock();
        mail.queued + mail.in_flight
    }

    /// Begin shutdown: refuse new pushes, let the worker drain what is
    /// already queued (controls included) and exit.
    pub(crate) fn begin_drain(&self) {
        let mut mail = self.mail.lock();
        mail.draining = true;
        drop(mail);
        self.not_empty.notify_all();
        self.space.notify_all();
    }
}

/// Worker-local per-stream state (exclusively owned — never locked).
struct Slot {
    session: StreamSession,
    /// last registry version this shard published for the stream
    last_version: Option<u64>,
    /// fair-scheduling weight (mirrored from the mailbox queue so a
    /// snapshot can persist it without taking the mail lock)
    weight: u32,
    /// state has changed since the last durable checkpoint
    dirty: bool,
    /// when this stream was last checkpointed (or created)
    last_ckpt: Instant,
}

impl Slot {
    fn new(session: StreamSession, weight: u32) -> Slot {
        Slot {
            session,
            last_version: None,
            weight,
            dirty: false,
            last_ckpt: Instant::now(),
        }
    }
}

fn summarize(slot: &Slot) -> StreamSummary {
    let solver = slot.session.solver();
    let (objective, rho) = if solver.is_empty() {
        (0.0, (0.0, 0.0))
    } else {
        (solver.report().stats.objective, solver.rho())
    };
    StreamSummary {
        name: slot.session.name().to_string(),
        updates: slot.session.updates(),
        retrains: slot.session.retrains(),
        version: slot.last_version,
        rho,
        objective,
    }
}

/// Reconcile a finished background retrain with its session: clear the
/// in-flight marker and re-baseline drift on the retrained offsets (or
/// the session's own freshest ones if an incremental publish already
/// hot-swapped over the retrained entry). Shared by the shard worker
/// (owning-shard hand-back) and the single-writer
/// `Coordinator::stream_push` path. Returns the completed registry
/// version, if a retrain landed.
pub(crate) fn reconcile_retrain(
    session: &mut StreamSession,
    registry: &ModelRegistry,
    jobs: &TrainQueue,
) -> Option<u64> {
    let id = session.pending_retrain()?;
    match jobs.status(id) {
        Some(JobStatus::Done { version, .. }) => {
            let rho = match registry.get_versioned(session.name()) {
                Some((m, v)) if v == version => (m.rho1, m.rho2),
                _ => session.solver().rho(),
            };
            session.retrain_finished(Some(rho));
            Some(version)
        }
        Some(JobStatus::Failed { .. }) | Some(JobStatus::Cancelled) | None => {
            // drop the marker; the next drift trip resubmits (a
            // Cancelled job was superseded — typically by a forget —
            // and its successor carries its own marker)
            session.retrain_finished(None);
            None
        }
        _ => None,
    }
}

/// Absorb one sample into a slot: hot-swap the refreshed model into the
/// registry and escalate a background retrain when drift tripped.
///
/// Tracing shape (only when the sample carries a trace id): `t_pop`
/// closes the Queue span and opens Absorb on the same timestamp, and
/// `t_done` closes Absorb and opens Publish — so the three stages tile
/// the enqueue→publish interval exactly and their durations sum to the
/// end-to-end push latency. The Gram/Repair sub-spans tile the tail of
/// Absorb from the solver's own per-push stage split.
fn absorb_one(
    slot: &mut Slot,
    sample: &QueuedSample,
    shard_idx: u32,
    registry: &ModelRegistry,
    jobs: &TrainQueue,
    stats: &ServiceStats,
) {
    // runtime form of the R2 invariant: the caller released the mail
    // lock before handing the batch here
    crate::sync::assert_lock_free("absorb");
    let trace = sample.trace;
    let sid =
        if trace != 0 { obs::stream_id(slot.session.name()) } else { 0 };
    let t_pop = if trace != 0 {
        let t = obs::now_us();
        obs::record(EventKind::AbsorbStart, trace, sid, shard_idx, 0);
        obs::record_span(obs::Span {
            trace,
            stage: Stage::Queue,
            start_us: sample.t_enq_us,
            dur_us: t.saturating_sub(sample.t_enq_us),
            stream: sid,
            shard: shard_idx,
            iters: 0,
        });
        t
    } else {
        0
    };
    let t0 = Instant::now();
    match slot.session.absorb(&sample.x) {
        Ok(absorbed) => {
            let t_done = if trace != 0 { obs::now_us() } else { 0 };
            if trace != 0 {
                let iters =
                    slot.session.solver().last_stats().iterations as u64;
                let (admit_us, repair_us) =
                    slot.session.solver().last_stage_us();
                obs::record_span(obs::Span {
                    trace,
                    stage: Stage::Absorb,
                    start_us: t_pop,
                    dur_us: t_done.saturating_sub(t_pop),
                    stream: sid,
                    shard: shard_idx,
                    iters,
                });
                obs::record_span(obs::Span {
                    trace,
                    stage: Stage::Gram,
                    start_us: t_done.saturating_sub(admit_us + repair_us),
                    dur_us: admit_us,
                    stream: sid,
                    shard: shard_idx,
                    iters: 0,
                });
                obs::record_span(obs::Span {
                    trace,
                    stage: Stage::Repair,
                    start_us: t_done.saturating_sub(repair_us),
                    dur_us: repair_us,
                    stream: sid,
                    shard: shard_idx,
                    iters,
                });
                obs::record(EventKind::AbsorbEnd, trace, sid, shard_idx, 0);
                obs::record(
                    EventKind::RepairIters,
                    trace,
                    sid,
                    shard_idx,
                    iters,
                );
            }
            if let Some(model) = absorbed.model {
                slot.last_version =
                    Some(registry.insert(slot.session.name(), model));
                if trace != 0 {
                    obs::record_span(obs::Span {
                        trace,
                        stage: Stage::Publish,
                        start_us: t_done,
                        dur_us: obs::now_us().saturating_sub(t_done),
                        stream: sid,
                        shard: shard_idx,
                        iters: 0,
                    });
                }
            }
            if absorbed.retrain_wanted {
                let id = jobs.submit(TrainRequest {
                    name: slot.session.name().to_string(),
                    dataset: slot.session.window_dataset(),
                    trainer: slot.session.retrain_trainer(),
                });
                slot.session.retrain_submitted(id);
                stats.stream_retrains.inc();
            }
            slot.dirty = true;
            stats.stream_absorbed.inc();
        }
        Err(e) => {
            // the producer already got Ok from push — record the loss
            // where it is diagnosable instead of folding it into the
            // scoring error counter
            crate::log_warn!(
                "stream",
                "stream '{}': absorb failed, sample dropped: {e}",
                slot.session.name()
            );
            stats.stream_absorb_errors.inc();
            obs::record(EventKind::ErrorRaised, trace, sid, shard_idx, 0);
            let _ = obs::postmortem_dump("absorb-error");
        }
    }
    stats.absorb_latency.record(t0.elapsed());
}

/// Serialize one session and hand the bytes to the writer thread. The
/// slot only goes clean when the writer actually accepted the bytes —
/// on a failed hand-off it stays dirty so the next due tick retries
/// (the cadence clock still advances, so a dead writer is a warning
/// per cadence, not a hot spin).
fn checkpoint_slot(slot: &mut Slot, sink: &CheckpointSink) {
    // serialization + the writer hand-off must not run under the mail
    // lock: producers would stall for the whole encode
    crate::sync::assert_lock_free("checkpoint serialize");
    let snap = Snapshot::capture(&slot.session, slot.weight, slot.last_version);
    let path = snapshot_path(&sink.cfg.dir, slot.session.name());
    if sink.tx.send((path, snap.encode())).is_ok() {
        slot.dirty = false;
    } else {
        crate::log_warn!(
            "stream",
            "stream '{}': checkpoint writer is gone, snapshot dropped",
            slot.session.name()
        );
    }
    slot.last_ckpt = Instant::now();
}

/// The shard worker loop. Exits once draining is requested and every
/// queue, control event and close acknowledgement has been retired —
/// in-flight background retrains do NOT block the exit (they are the
/// train queue's to finish; the session is checkpointed a final time
/// when checkpointing is on, then dropped).
pub(crate) fn run_worker(
    shard: Arc<Shard>,
    registry: Arc<ModelRegistry>,
    jobs: Arc<TrainQueue>,
    stats: Arc<ServiceStats>,
    ckpt: Option<CheckpointSink>,
) {
    /// Records WorkerExit on every way out of the loop; when the exit
    /// is an unwind (an invariant assertion fired somewhere below), the
    /// flight recorder is dumped to a postmortem file so the events
    /// leading up to the death survive the thread.
    struct ExitGuard(u32);
    impl Drop for ExitGuard {
        fn drop(&mut self) {
            obs::record(EventKind::WorkerExit, 0, 0, self.0, 0);
            if std::thread::panicking() {
                let _ = obs::postmortem_dump("shard-worker");
            }
        }
    }
    let _exit = ExitGuard(shard.idx);
    let mut slots: HashMap<String, Slot> = HashMap::new();
    let mut closing: HashMap<String, Sender<Result<StreamSummary>>> =
        HashMap::new();
    loop {
        // Take work. Controls are drained in the same critical section
        // as the data pop, and a stream's queue entry is created in the
        // same critical section as its Open control, so a session always
        // exists (processed below, before the absorb) by the time its
        // first sample is popped.
        let (controls, batch, draining) = {
            let mut mail = shard.mail.lock();
            let controls: Vec<Control> = mail.control.drain(..).collect();
            let batch = mail.pop_fair();
            (controls, batch, mail.draining)
        };

        for c in controls {
            match c {
                Control::Open { name, cfg, weight } => {
                    let session = StreamSession::new(name.clone(), cfg);
                    slots.insert(name, Slot::new(session, weight));
                }
                Control::Adopt { name, session, last_version, ack } => {
                    let weight = {
                        let mail = shard.mail.lock();
                        mail.queues.get(&name).map_or(1, |q| q.weight)
                    };
                    let mut slot = Slot::new(*session, weight);
                    // resume serving immediately: re-publish the
                    // restored model at (or past) the pre-restart
                    // version so scorers and version watchers continue
                    // seamlessly
                    if slot.session.is_warm() {
                        let v = registry.insert_with_floor(
                            slot.session.name(),
                            slot.session.solver().model(),
                            last_version + 1,
                        );
                        slot.last_version = Some(v);
                    }
                    stats.stream_restores.inc();
                    let version = slot.last_version;
                    slots.insert(name, slot);
                    let _ = ack.send(version);
                }
                Control::Close { name, ack } => {
                    closing.insert(name, ack);
                }
                Control::Forget { name, ids, ack } => {
                    let res = match slots.get_mut(&name) {
                        None => Err(Error::Coordinator(format!(
                            "unknown stream '{name}'"
                        ))),
                        Some(slot) => match slot.session.forget_many(&ids) {
                            Ok(f) => {
                                // an in-flight background retrain was
                                // trained on a window that still held
                                // the forgotten sample: cancel it
                                // BEFORE publishing the post-removal
                                // model — a stale fit finishing in the
                                // gap would otherwise land at a HIGHER
                                // version than the clean model. With
                                // this order, either the cancel wins
                                // (the stale model never publishes) or
                                // a just-finished Done is immediately
                                // superseded by the insert below (or,
                                // for a below-warmup session that skips
                                // the insert, by the replacement
                                // retrain).
                                if f.retrain_stale {
                                    if let Some(old) =
                                        slot.session.pending_retrain()
                                    {
                                        jobs.cancel(old);
                                    }
                                }
                                for &id in &ids {
                                    obs::record(
                                        EventKind::Forget,
                                        0,
                                        obs::stream_id(&name),
                                        shard.idx,
                                        id,
                                    );
                                }
                                // hot-swap the post-removal model so the
                                // served slab stops reflecting the
                                // forgotten sample immediately
                                let version = f.model.map(|model| {
                                    let v = registry
                                        .insert(slot.session.name(), model);
                                    slot.last_version = Some(v);
                                    v
                                });
                                // and retrain on the post-removal window
                                // in the cancelled job's place
                                if f.retrain_stale {
                                    let rid = jobs.submit(TrainRequest {
                                        name: slot
                                            .session
                                            .name()
                                            .to_string(),
                                        dataset: slot
                                            .session
                                            .window_dataset(),
                                        trainer: slot
                                            .session
                                            .retrain_trainer(),
                                    });
                                    slot.session.retrain_submitted(rid);
                                    stats.stream_retrains.inc();
                                }
                                slot.dirty = true;
                                stats.stream_forgets.add(ids.len() as u64);
                                Ok(ForgetOutcome {
                                    name: name.clone(),
                                    ids,
                                    version,
                                    resident: f.resident,
                                })
                            }
                            // typed rejection (non-resident id, last
                            // sample): the stream keeps running — the
                            // error travels to the caller, never a
                            // worker panic
                            Err(e) => Err(e),
                        },
                    };
                    let _ = ack.send(res);
                }
                Control::Snapshot { dir, ack } => {
                    // Front-door sweep: write every owned session, one
                    // result per stream — a failing write is isolated
                    // to its stream. Writes run synchronously on the
                    // worker ON PURPOSE: `snapshot_streams` promises
                    // durable-on-return (the E2E kill/restore contract
                    // rests on it). Absorption pauses for the sweep,
                    // but producers keep enqueuing up to the per-stream
                    // mailbox bound, and the documented protocol is to
                    // quiesce first anyway.
                    let mut results = Vec::with_capacity(slots.len());
                    for slot in slots.values_mut() {
                        let snap = Snapshot::capture(
                            &slot.session,
                            slot.weight,
                            slot.last_version,
                        );
                        let path =
                            snapshot_path(&dir, slot.session.name());
                        let res = super::persist::write_atomic(
                            &path,
                            &snap.encode(),
                        );
                        if res.is_ok() {
                            slot.dirty = false;
                            slot.last_ckpt = Instant::now();
                            stats.stream_checkpoints.inc();
                            obs::record(
                                EventKind::CheckpointWritten,
                                0,
                                obs::stream_id(slot.session.name()),
                                shard.idx,
                                0,
                            );
                        } else {
                            stats.stream_checkpoint_errors.inc();
                        }
                        results
                            .push((slot.session.name().to_string(), res));
                    }
                    let _ = ack.send(results);
                }
            }
        }

        let had_batch = batch.is_some();
        if let Some((name, samples, backlog)) = batch {
            if let Some(slot) = slots.get_mut(&name) {
                // Adaptive repair budget: this stream's own remaining
                // backlog (relative to the mailbox bound) scales down
                // its repair iteration budget and publish cadence — a
                // hot drifting tenant degrades its own freshness, not
                // its shard-mates' latency. Pressure 0 restores the
                // configured budget exactly.
                let pressure =
                    (backlog as f64 / shard.cap.max(1) as f64).clamp(0.0, 1.0);
                slot.session.set_pressure(pressure);
                for s in &samples {
                    absorb_one(slot, s, shard.idx, &registry, &jobs, &stats);
                }
            }
            let mut mail = shard.mail.lock();
            mail.in_flight -= samples.len();
            drop(mail);
            shard.space.notify_all();
        }

        // Owning-shard retrain hand-back: completed background retrains
        // re-baseline their session here, on the shard that owns it.
        let mut pending_retrains = false;
        for slot in slots.values_mut() {
            if reconcile_retrain(&mut slot.session, &registry, &jobs)
                .is_some()
            {
                slot.dirty = true;
            }
            pending_retrains |= slot.session.pending_retrain().is_some();
        }

        // Periodic checkpoint: at most ONE due session per tick (the
        // absorb hot path is never blocked longer than one serialize);
        // the writer thread does the disk I/O.
        if let Some(sink) = &ckpt {
            let due = slots
                .values_mut()
                .filter(|s| s.dirty && s.last_ckpt.elapsed() >= sink.cfg.every)
                .max_by_key(|s| s.last_ckpt.elapsed());
            if let Some(slot) = due {
                checkpoint_slot(slot, sink);
            }
        }

        // Finalize closes whose queues have fully drained. The emptiness
        // check and the queue removal happen in ONE critical section: a
        // racing push that already passed the route lookup may still land
        // a sample, and a bare check-then-remove would silently drop it —
        // the "absorbs are never lost" invariant holds only if a late
        // sample defers the finalize to a later tick instead.
        if !closing.is_empty() {
            let candidates: Vec<String> = closing.keys().cloned().collect();
            for name in candidates {
                let drained = {
                    let mut mail = shard.mail.lock();
                    let empty = match mail.queues.get(&name) {
                        Some(q) => q.samples.is_empty(),
                        None => true,
                    };
                    if empty {
                        mail.remove_stream(&name);
                    }
                    empty
                };
                if !drained {
                    continue; // a late push landed; absorb it first
                }
                let Some(ack) = closing.remove(&name) else { continue };
                let summary = slots.remove(&name).map(|mut slot| {
                    // final checkpoint: a graceful close persists the
                    // freshest state for a later restore
                    if let Some(sink) = &ckpt {
                        if slot.dirty {
                            checkpoint_slot(&mut slot, sink);
                        }
                    }
                    summarize(&slot)
                });
                shard.space.notify_all();
                let _ = ack.send(summary.ok_or_else(|| {
                    Error::Coordinator(format!("unknown stream '{name}'"))
                }));
            }
        }

        if draining {
            let done = {
                let mail = shard.mail.lock();
                mail.queued == 0
                    && mail.in_flight == 0
                    && mail.control.is_empty()
                    && closing.is_empty()
            };
            if done {
                // final checkpoints on the way out: a graceful
                // shutdown leaves every session restorable at its
                // freshest state
                if let Some(sink) = &ckpt {
                    for slot in slots.values_mut() {
                        if slot.dirty {
                            checkpoint_slot(slot, sink);
                        }
                    }
                }
                shard.space.notify_all();
                return;
            }
            continue;
        }

        if !had_batch {
            // Idle: sleep until data/control arrives (push, open, close
            // and begin_drain all notify `not_empty`, and the lock is
            // held from the emptiness check to the wait, so no wakeup is
            // missed). A pending background retrain needs a poll (the
            // train queue cannot notify this shard), and a dirty session
            // needs a timed wake at its next checkpoint due time —
            // otherwise an idle shard would defer periodic durability
            // until the next push.
            let next_ckpt = ckpt.as_ref().and_then(|sink| {
                slots
                    .values()
                    .filter(|s| s.dirty)
                    .map(|s| sink.cfg.every.saturating_sub(s.last_ckpt.elapsed()))
                    .min()
            });
            let mail = shard.mail.lock();
            if mail.queued == 0 && mail.control.is_empty() && !mail.draining {
                if pending_retrains {
                    let _ = shard
                        .not_empty
                        .wait_timeout(mail, Duration::from_millis(5));
                } else if let Some(due_in) = next_ckpt {
                    let _ = shard.not_empty.wait_timeout(
                        mail,
                        due_in.max(Duration::from_millis(1)),
                    );
                } else {
                    let _ = shard.not_empty.wait(mail);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mailbox_with(streams: &[(&str, u32, usize)]) -> Mailbox {
        // (name, weight, queued samples)
        let mut m = Mailbox::new();
        for &(name, weight, n) in streams {
            let mut q = VecDeque::new();
            for i in 0..n {
                q.push_back(QueuedSample {
                    x: vec![i as f64],
                    trace: 0,
                    t_enq_us: 0,
                });
            }
            m.queued += n;
            m.queues.insert(
                name.to_string(),
                StreamQueue { samples: q, weight, dim: 1 },
            );
            m.order.push(name.to_string());
        }
        m
    }

    #[test]
    fn pop_fair_round_robins_across_streams() {
        // hot stream with a deep queue cannot starve its shard-mates
        let mut m = mailbox_with(&[("hot", 1, 100), ("cold", 1, 3)]);
        let mut service = Vec::new();
        while let Some((name, batch, _)) = m.pop_fair() {
            assert_eq!(batch.len(), 1);
            service.push(name);
        }
        // cold's 3 samples are served within the first 6 visits
        let cold_positions: Vec<usize> = service
            .iter()
            .enumerate()
            .filter(|(_, n)| n.as_str() == "cold")
            .map(|(i, _)| i)
            .collect();
        assert_eq!(cold_positions.len(), 3);
        assert!(
            *cold_positions.last().unwrap() <= 5,
            "cold starved: served at {cold_positions:?}"
        );
        assert_eq!(service.len(), 103);
        assert_eq!(m.queued, 0);
        assert_eq!(m.in_flight, 103);
    }

    #[test]
    fn pop_fair_respects_weights() {
        let mut m = mailbox_with(&[("a", 3, 9), ("b", 1, 3)]);
        let mut sizes = Vec::new();
        while let Some((name, batch, _)) = m.pop_fair() {
            sizes.push((name, batch.len()));
        }
        // a gets 3 per visit, b gets 1 per visit, alternating
        assert_eq!(
            sizes,
            vec![
                ("a".to_string(), 3),
                ("b".to_string(), 1),
                ("a".to_string(), 3),
                ("b".to_string(), 1),
                ("a".to_string(), 3),
                ("b".to_string(), 1),
            ]
        );
    }

    #[test]
    fn pop_fair_empty_and_single() {
        let mut m = Mailbox::new();
        assert!(m.pop_fair().is_none());
        let mut m = mailbox_with(&[("only", 2, 5)]);
        let (n, b, backlog) = m.pop_fair().unwrap();
        assert_eq!((n.as_str(), b.len(), backlog), ("only", 2, 3));
    }

    #[test]
    fn remove_stream_fixes_cursor_and_counts() {
        let mut m = mailbox_with(&[("a", 1, 2), ("b", 1, 2), ("c", 1, 2)]);
        let (first, _, _) = m.pop_fair().unwrap();
        assert_eq!(first, "a");
        assert_eq!(m.cursor, 1);
        m.remove_stream("a"); // removed index 0 < cursor -> cursor shifts
        assert_eq!(m.cursor, 0);
        // 6 queued - 1 popped - a's 1 remaining (dropped with the queue)
        assert_eq!(m.queued, 4);
        let (next, _, _) = m.pop_fair().unwrap();
        assert_eq!(next, "b");
        m.remove_stream("b");
        m.remove_stream("c");
        assert_eq!(m.queued, 0);
        assert!(m.pop_fair().is_none());
        assert_eq!(m.cursor, 0);
    }

    #[test]
    fn shard_push_rejects_unknown_stream() {
        let shard = Shard::new(0, 8);
        let stats = ServiceStats::new();
        assert!(shard.push("ghost", &[0.0, 0.0], 0, 0, &stats).is_err());
    }

    #[test]
    fn shard_push_rejects_dimension_mismatch() {
        let shard = Shard::new(0, 8);
        let stats = ServiceStats::new();
        assert!(shard.open("s", StreamConfig::default(), 1)); // dim = 2
        assert!(shard.push("s", &[1.0, 2.0, 3.0], 0, 0, &stats).is_err());
        assert!(shard.push("s", &[1.0], 0, 0, &stats).is_err());
        assert_eq!(shard.queue_depth(), 0, "bad samples must not queue");
    }

    #[test]
    fn shard_try_push_sheds_at_capacity() {
        let shard = Shard::new(0, 1);
        let stats = ServiceStats::new();
        assert!(shard.open("s", StreamConfig::default(), 1)); // dim = 2
        shard.try_push("s", &[1.0, 2.0], 0, 0, &stats).unwrap();
        match shard.try_push("s", &[3.0, 4.0], 0, 0, &stats) {
            Err(Error::Saturated { depth }) => assert_eq!(depth, 1),
            other => panic!("expected Saturated, got {other:?}"),
        }
        // shedding is not blocking: the backpressure counter (blocked
        // wait slices) must stay untouched
        assert_eq!(stats.stream_backpressure.get(), 0);
        assert_eq!(shard.queue_depth(), 1);
    }

    #[test]
    fn shard_open_rejected_while_draining() {
        let shard = Shard::new(0, 8);
        shard.begin_drain();
        assert!(!shard.open("late", StreamConfig::default(), 1));
        let stats = ServiceStats::new();
        assert!(shard.push("late", &[0.0, 0.0], 0, 0, &stats).is_err());
    }
}
