//! # slabsvm — SMO for One-Class Slab Support Vector Machines
//!
//! Production-shaped reproduction of *"Sequential Minimal Optimization for
//! One-Class Slab Support Vector Machine"* (Kumar et al., IIIT Allahabad;
//! a.k.a. "A fast learning algorithm for One-Class Slab SVMs"), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build-time Python)** — Pallas kernels for the Gram
//!   matrix, batched slab decision function and KKT sweeps, composed into
//!   JAX graphs and AOT-lowered to HLO text artifacts (`python/compile/`).
//! * **Layer 3 (this crate)** — the paper's contribution: the OCSSVM
//!   **SMO solver** ([`solver::smo`]), its working-set heuristic, the
//!   baselines it is compared against ([`solver::qp_pg`],
//!   [`solver::qp_ipm`], [`solver::ocsvm_smo`]) — all behind the unified
//!   [`solver::api`] — and a serving coordinator ([`coordinator`]) that
//!   batches scoring requests onto the PJRT-compiled artifacts
//!   ([`runtime`]).
//!
//! Python never runs at request time: once `make artifacts` has produced
//! `artifacts/*.hlo.txt`, the `slabsvm` binary is self-contained.
//!
//! ## Quick start
//!
//! Every solver trains through one entry point: pick a
//! [`solver::SolverKind`], configure a [`solver::Trainer`], call `fit`.
//! The returned [`solver::FitReport`] carries the model, the full dual
//! point, effort stats and a KKT certificate.
//!
//! ```no_run
//! use slabsvm::data::synthetic::SlabConfig;
//! use slabsvm::kernel::Kernel;
//! use slabsvm::solver::{SolverKind, Trainer};
//!
//! let ds = SlabConfig::default().generate(1000, 42);
//! // the paper's constants: nu1 = 0.5, nu2 = 0.01, eps = 2/3
//! let report = Trainer::new(SolverKind::Smo)
//!     .kernel(Kernel::Linear)
//!     .nu1(0.5)
//!     .nu2(0.01)
//!     .eps(2.0 / 3.0)
//!     .fit(&ds.x)
//!     .unwrap();
//! let label = report.model.classify(ds.x.row(0)); // +1 inside the slab
//! assert!(report.certificate.max_kkt_violation.is_finite());
//! # let _ = label;
//! ```
//!
//! Swapping `SolverKind::Smo` for `::Pg`, `::Ipm` or `::OcsvmSmo`
//! changes nothing else — that is the point: benches, examples and the
//! coordinator dispatch over [`solver::SolverKind`] instead of
//! per-module `train` functions. Warm starts, cascade sharding and
//! bounded kernel-row caches are [`solver::Trainer`] layers
//! (`.warm_start(n)`, `.cascade(shards, rounds)`,
//! `.cache_rows(cap, policy)`) that compose on top. So is the compute
//! mode: `.precision(kernel::Precision::F32)` solves on a
//! single-precision Gram and then **re-certifies in f64** — if the
//! KKT certificate misses, the trainer redoes the fit at full
//! precision and says so (`FitReport::fell_back`); an f32 fit is
//! never returned uncertified (DESIGN.md §5).
//!
//! When the exact O(m²) Gram no longer fits the problem, switch the
//! **engine** instead of the solver: `.engine(..)` trains the same
//! slab on an explicit feature map — random Fourier features for the
//! RBF kernel or a Nyström landmark map for any kernel — so memory is
//! O(m·D) and scoring is one D-dimensional dot product, independent
//! of the training size (DESIGN.md §10):
//!
//! ```no_run
//! use slabsvm::data::synthetic::SlabConfig;
//! use slabsvm::kernel::featmap::EngineKind;
//! use slabsvm::kernel::Kernel;
//! use slabsvm::solver::{SolverKind, Trainer};
//!
//! let ds = SlabConfig::default().generate(100_000, 42);
//! let report = Trainer::new(SolverKind::Approx)
//!     .kernel(Kernel::Rbf { g: 0.5 })
//!     .engine(EngineKind::Rff) // or EngineKind::Nystroem
//!     .features(256)           // lifted dimension D
//!     .seed(7)                 // bitwise-reproducible map
//!     .fit(&ds.x)
//!     .unwrap();
//! assert!(report.certificate.max_kkt_violation.is_finite());
//! ```
//!
//! For unbounded sample streams the [`stream`] layer keeps a model
//! current without batch retrains — incremental/decremental SMO over a
//! sliding window, with drift-triggered background retrains:
//!
//! ```no_run
//! use slabsvm::stream::{StreamConfig, StreamSession};
//! let mut session = StreamSession::new("live", StreamConfig::default());
//! let absorbed = session.absorb(&[20.0, 3.0]).unwrap(); // one sample in
//! let _model = absorbed.model; // fresh model, once warm
//! // (drive through Coordinator::open_stream/stream_push to hot-swap
//! //  the served model version and escalate retrains on drift)
//! ```
//!
//! Many concurrent streams go through the sharded session manager
//! instead — sessions hashed across shard worker threads, bounded
//! mailboxes with backpressure, weighted-fair scheduling per shard:
//!
//! ```no_run
//! use slabsvm::coordinator::{BatcherConfig, Coordinator};
//! use slabsvm::runtime::Engine;
//! use slabsvm::stream::{StreamConfig, StreamSpec};
//! let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 2);
//! c.open_streams(vec![StreamSpec::new("tenant-a", StreamConfig::default())])
//!     .unwrap();
//! c.push("tenant-a", &[20.0, 3.0]).unwrap(); // any thread, any tenant
//! let summary = c.close_stream("tenant-a").unwrap(); // drains, reports
//! # let _ = summary.updates;
//! ```
//!
//! Windows evict by a pluggable policy ([`stream::policy`]) — FIFO, or
//! `interior-first` (evict the smallest-|α−ᾱ| resident so support
//! vectors stay; a smaller window then holds a larger FIFO window's
//! accuracy) — and the same arbitrary-slot removal path gives targeted
//! **unlearning**: forget any resident sample by its stable id (its
//! 0-based arrival index) for the cost of one warm-started repair
//! sweep, no retrain:
//!
//! ```no_run
//! use slabsvm::stream::{PolicyKind, StreamConfig, StreamSession};
//! let mut cfg = StreamConfig::default();
//! cfg.incremental.policy = PolicyKind::InteriorFirst;
//! let mut session = StreamSession::new("live", cfg);
//! let first = session.absorb(&[20.0, 3.0]).unwrap();
//! session.absorb(&[21.0, 2.0]).unwrap();
//! let f = session.forget(first.sample_id).unwrap(); // "forget user X"
//! assert_eq!(f.resident, 1); // dual mass withdrawn, KKT repaired
//! // "delete ALL of user X": forget_many(&[id, …]) withdraws the
//! // whole batch with a single repair sweep (all-or-nothing)
//! // on a managed fleet: Coordinator::forget_many("tenant", &ids);
//! // at rest: `slabsvm forget --snapshot f.snap --id 7,8`
//! ```
//!
//! Sessions are durable ([`stream::persist`]): snapshot a session (or
//! a whole fleet via `Coordinator::snapshot_streams`) and a restarted
//! process resumes it from the persisted window + dual state — a
//! bounded warm-started repair instead of a cold window refill:
//!
//! ```no_run
//! use slabsvm::stream::{StreamConfig, StreamSession};
//! let mut session = StreamSession::new("live", StreamConfig::default());
//! session.absorb(&[20.0, 3.0]).unwrap();
//! let bytes = session.snapshot(); // versioned, checksummed, bitwise
//! // …process restarts…
//! let resumed = StreamSession::restore(&bytes).unwrap();
//! assert_eq!(resumed.updates(), 1); // counters, window, dual: intact
//! ```
//!
//! The old per-module free functions (`solver::smo::train`,
//! `solver::qp_pg::train`, …) still work but are `#[deprecated]` shims
//! over this API; see CHANGES.md for the deprecation path.
//!
//! ## Metrics & tracing
//!
//! The [`obs`] layer (DESIGN.md §8) makes the serving stack's latency
//! legible without a dependency: every `ServiceStats` counter and
//! histogram exports through one registry as Prometheus text or JSON
//! lines, and — with the recorder enabled — each `Coordinator::push`
//! gets a trace id whose queue/absorb/repair/publish stages are
//! recorded as contiguous spans (solver iteration counts attached):
//!
//! ```no_run
//! use slabsvm::coordinator::{BatcherConfig, Coordinator};
//! use slabsvm::runtime::Engine;
//! use slabsvm::stream::{StreamConfig, StreamSpec};
//!
//! slabsvm::obs::set_enabled(true); // or SLABSVM_OBS=1; default off
//! let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 2);
//! c.open_streams(vec![StreamSpec::new("t", StreamConfig::default())])
//!     .unwrap();
//! c.push("t", &[20.0, 3.0]).unwrap();
//! c.quiesce_streams();
//! println!("{}", c.metrics_text()); // Prometheus text exposition
//! for span in slabsvm::obs::recent_spans(16) {
//!     println!("{}", span.to_json()); // queue/absorb/publish chain
//! }
//! ```
//!
//! Disabled (the default), the recorder is a relaxed atomic load per
//! would-be event — the absorb hot path stays allocation-free. The
//! `slabsvm stats` and `slabsvm trace` CLI verbs drive the same
//! surfaces against a short synthetic workload.
//!
//! ## Network serving
//!
//! The [`serve`] layer (DESIGN.md §9) puts the whole coordinator
//! surface behind a dependency-free HTTP/1.1 front door — per-tenant
//! bearer-token auth, a connection cap, token-bucket rate limiting,
//! and graceful degradation: a saturated stream mailbox is `429` +
//! `Retry-After` (via the non-blocking `Coordinator::try_push`), and
//! scoring under batcher saturation answers from the last published
//! model with `X-Slab-Stale: 1` instead of failing:
//!
//! ```no_run
//! use std::sync::Arc;
//! use slabsvm::coordinator::{BatcherConfig, Coordinator};
//! use slabsvm::runtime::Engine;
//! use slabsvm::serve::{self, Router, RouterConfig, ServerConfig};
//!
//! let coord = Arc::new(Coordinator::start(
//!     Engine::Native,
//!     BatcherConfig::default(),
//!     2,
//! ));
//! let router = Arc::new(Router::new(coord, RouterConfig::default()));
//! let server = serve::start(router, ServerConfig::default()).unwrap();
//! println!("listening on {}", server.addr());
//! // POST /v1/score/{model}, /v1/streams/{name}/push, GET /metrics …
//! ```
//!
//! `slabsvm serve` is the CLI face of the same stack, and the
//! `serve-smoke` CI lane exercises it end to end with a Python client.
//!
//! ## Invariant enforcement
//!
//! The concurrency and panic-freedom rules the serving stack relies on
//! are enforced mechanically (DESIGN.md §7): `cargo run -p slablint`
//! statically lints the source for rules R1–R5 (panic-capable sites in
//! the data plane, guards held across absorbs/sends, hot-loop
//! allocations, counter completeness, doc cross-references), and the
//! **`lock-audit`** cargo feature swaps every lock in the shard/
//! manager/job layer for a tracked variant ([`sync`]) that builds a
//! global lock-order graph at runtime, panics on a would-be deadlock
//! cycle, and asserts that no tracked lock is held across an absorb.
//! The feature costs nothing when disabled (plain `std::sync`
//! newtypes); unit tests always track, and CI runs the concurrency
//! suite with `--features lock-audit`.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod bench;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod figures;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod stream;
pub mod sync;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
