//! # slabsvm — SMO for One-Class Slab Support Vector Machines
//!
//! Production-shaped reproduction of *"Sequential Minimal Optimization for
//! One-Class Slab Support Vector Machine"* (Kumar et al., IIIT Allahabad;
//! a.k.a. "A fast learning algorithm for One-Class Slab SVMs"), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build-time Python)** — Pallas kernels for the Gram
//!   matrix, batched slab decision function and KKT sweeps, composed into
//!   JAX graphs and AOT-lowered to HLO text artifacts (`python/compile/`).
//! * **Layer 3 (this crate)** — the paper's contribution: the OCSSVM
//!   **SMO solver** ([`solver::smo`]), its working-set heuristic, the
//!   baselines it is compared against ([`solver::qp_pg`],
//!   [`solver::qp_ipm`], [`solver::ocsvm_smo`]), and a serving
//!   coordinator ([`coordinator`]) that batches scoring requests onto the
//!   PJRT-compiled artifacts ([`runtime`]).
//!
//! Python never runs at request time: once `make artifacts` has produced
//! `artifacts/*.hlo.txt`, the `slabsvm` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use slabsvm::data::synthetic::SlabConfig;
//! use slabsvm::kernel::Kernel;
//! use slabsvm::solver::smo::{SmoParams, train};
//!
//! let ds = SlabConfig::default().generate(1000, 42);
//! let params = SmoParams { nu1: 0.5, nu2: 0.01, eps: 2.0 / 3.0, ..Default::default() };
//! let model = train(&ds.x, Kernel::Linear, &params).unwrap();
//! let label = model.classify(&ds.x.row(0)); // +1 inside the slab
//! # let _ = label;
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod bench;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod figures;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod solver;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
