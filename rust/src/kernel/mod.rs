//! Kernel functions + Gram helpers (the native compute path).
//!
//! Mirrors `python/compile/kernels/ref.py` exactly — the kernel-id
//! mapping and hyper-parameter semantics must match so the native and
//! PJRT engines are interchangeable (engine-equivalence is asserted in
//! `rust/tests/runtime_roundtrip.rs`).

use crate::linalg::{dot, Matrix};
use crate::util::threadpool;

/// Kernel family + hyper-parameters.
///
/// Ids used on the wire (artifact names / params vectors):
/// 0 linear, 1 rbf, 2 poly, 3 sigmoid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// k(x,y) = <x,y>  (the paper's experiments use this)
    Linear,
    /// k(x,y) = exp(-g ||x-y||^2)
    Rbf { g: f64 },
    /// k(x,y) = (g <x,y> + c)^degree
    Poly { g: f64, c: f64, degree: f64 },
    /// k(x,y) = tanh(g <x,y> + c)
    Sigmoid { g: f64, c: f64 },
}

impl Kernel {
    /// Artifact family name (matches aot.py FAMILY_NAMES).
    pub fn family(&self) -> &'static str {
        match self {
            Kernel::Linear => "linear",
            Kernel::Rbf { .. } => "rbf",
            Kernel::Poly { .. } => "poly",
            Kernel::Sigmoid { .. } => "sigmoid",
        }
    }

    /// (g, c, degree) params vector fed to the PJRT artifacts.
    pub fn params3(&self) -> [f32; 3] {
        match *self {
            Kernel::Linear => [0.0, 0.0, 0.0],
            Kernel::Rbf { g } => [g as f32, 0.0, 0.0],
            Kernel::Poly { g, c, degree } => [g as f32, c as f32, degree as f32],
            Kernel::Sigmoid { g, c } => [g as f32, c as f32, 0.0],
        }
    }

    /// Evaluate k(a, b).
    #[inline]
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match *self {
            Kernel::Linear => dot(a, b),
            Kernel::Rbf { g } => (-g * crate::linalg::sq_dist(a, b)).exp(),
            Kernel::Poly { g, c, degree } => (g * dot(a, b) + c).powf(degree),
            Kernel::Sigmoid { g, c } => (g * dot(a, b) + c).tanh(),
        }
    }

    /// Fill `out[j] = k(x_row, x[j])` for all rows j of `x`.
    pub fn row(&self, x: &Matrix, row: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), x.rows());
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.eval(row, x.row(j));
        }
    }

    /// Full Gram matrix, parallel over row blocks, exploiting symmetry.
    pub fn gram(&self, x: &Matrix, threads: usize) -> Matrix {
        let n = x.rows();
        let mut k = Matrix::zeros(n, n);
        // Parallel over rows; each worker fills the upper triangle of its
        // rows (j >= i) — the mirror pass below completes the matrix.
        threadpool::parallel_rows(k.data_mut(), n, threads, |start, rows| {
            for (r, out) in rows.chunks_mut(n).enumerate() {
                let i = start + r;
                let xi = x.row(i);
                for j in i..n {
                    out[j] = self.eval(xi, x.row(j));
                }
            }
        });
        // mirror upper -> lower
        for i in 0..n {
            for j in 0..i {
                let v = k.get(j, i);
                k.set(i, j, v);
            }
        }
        k
    }

    /// Cross-kernel matrix K[i][j] = k(x_i, q_j).
    pub fn cross(&self, x: &Matrix, q: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), q.cols());
        let (n, m) = (x.rows(), q.rows());
        let mut k = Matrix::zeros(n, m);
        threadpool::parallel_rows(k.data_mut(), m, threads, |start, rows| {
            for (r, out) in rows.chunks_mut(m).enumerate() {
                let xi = x.row(start + r);
                for (j, o) in out.iter_mut().enumerate() {
                    *o = self.eval(xi, q.row(j));
                }
            }
        });
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let data = (0..n * d).map(|_| rng.normal()).collect();
        Matrix::from_vec(n, d, data)
    }

    #[test]
    fn linear_is_dot() {
        let k = Kernel::Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn rbf_properties() {
        let k = Kernel::Rbf { g: 0.5 };
        let a = [1.0, -2.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-12);
        let b = [100.0, 100.0];
        assert!(k.eval(&a, &b) < 1e-10);
        assert!(k.eval(&a, &b) >= 0.0);
    }

    #[test]
    fn poly_matches_formula() {
        let k = Kernel::Poly { g: 2.0, c: 1.0, degree: 3.0 };
        // (2*11 + 1)^3 = 23^3
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 23f64.powi(3)).abs() < 1e-9);
    }

    #[test]
    fn sigmoid_matches_formula() {
        let k = Kernel::Sigmoid { g: 0.1, c: -0.5 };
        let want = (0.1 * 11.0 - 0.5f64).tanh();
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - want).abs() < 1e-12);
    }

    #[test]
    fn gram_symmetric_and_correct() {
        let x = rand_matrix(50, 3, 1);
        for k in [
            Kernel::Linear,
            Kernel::Rbf { g: 0.7 },
            Kernel::Poly { g: 0.5, c: 1.0, degree: 2.0 },
            Kernel::Sigmoid { g: 0.2, c: 0.1 },
        ] {
            let g = k.gram(&x, 4);
            for i in 0..50 {
                for j in 0..50 {
                    assert!(
                        (g.get(i, j) - k.eval(x.row(i), x.row(j))).abs() < 1e-12,
                        "mismatch at ({i},{j}) for {k:?}"
                    );
                    assert_eq!(g.get(i, j), g.get(j, i));
                }
            }
        }
    }

    #[test]
    fn gram_thread_invariance() {
        let x = rand_matrix(64, 4, 2);
        let k = Kernel::Rbf { g: 0.3 };
        let g1 = k.gram(&x, 1);
        let g8 = k.gram(&x, 8);
        assert_eq!(g1.data(), g8.data());
    }

    #[test]
    fn cross_matches_eval() {
        let x = rand_matrix(20, 3, 3);
        let q = rand_matrix(7, 3, 4);
        let k = Kernel::Rbf { g: 1.1 };
        let c = k.cross(&x, &q, 3);
        for i in 0..20 {
            for j in 0..7 {
                assert!((c.get(i, j) - k.eval(x.row(i), q.row(j))).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn row_matches_gram() {
        let x = rand_matrix(30, 2, 5);
        let k = Kernel::Linear;
        let g = k.gram(&x, 2);
        let mut row = vec![0.0; 30];
        k.row(&x, x.row(17), &mut row);
        for j in 0..30 {
            assert_eq!(row[j], g.get(17, j));
        }
    }

    #[test]
    fn params3_layout() {
        assert_eq!(Kernel::Rbf { g: 0.5 }.params3(), [0.5, 0.0, 0.0]);
        assert_eq!(
            Kernel::Poly { g: 1.0, c: 2.0, degree: 3.0 }.params3(),
            [1.0, 2.0, 3.0]
        );
    }
}
