//! Kernel functions + Gram helpers (the native compute path).
//!
//! Mirrors `python/compile/kernels/ref.py` exactly — the kernel-id
//! mapping and hyper-parameter semantics must match so the native and
//! PJRT engines are interchangeable (engine-equivalence is asserted in
//! `rust/tests/runtime_roundtrip.rs`).

use crate::linalg::{dot, dot_f32, sq_dist, sq_dist_f32, Matrix};
use crate::util::threadpool;

pub mod featmap;

/// Floating-point width for kernel/Gram compute.
///
/// `F64` is the reference mode: every result is bitwise pinned by the
/// parity and persistence suites. `F32` runs the Gram contraction at
/// single precision (roughly 2x the lane width on the same vector
/// units) and widens each entry back to f64 for the solver; any fit
/// made in `F32` mode must pass the f64 KKT certificate or the trainer
/// visibly falls back to a full f64 fit (`FitReport::fell_back`).
/// The streaming window Gram and snapshot checksums always stay f64 —
/// `F32` accelerates batch fits and background retrains only.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Reference double-precision compute (bitwise-pinned paths).
    #[default]
    F64,
    /// Single-precision Gram build, certified against the f64 KKT
    /// checker with automatic fallback.
    F32,
}

/// Kernel family + hyper-parameters.
///
/// Ids used on the wire (artifact names / params vectors):
/// 0 linear, 1 rbf, 2 poly, 3 sigmoid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// k(x,y) = <x,y>  (the paper's experiments use this)
    Linear,
    /// k(x,y) = exp(-g ||x-y||^2)
    Rbf { g: f64 },
    /// k(x,y) = (g <x,y> + c)^degree
    Poly { g: f64, c: f64, degree: f64 },
    /// k(x,y) = tanh(g <x,y> + c)
    Sigmoid { g: f64, c: f64 },
}

impl Kernel {
    /// Artifact family name (matches aot.py FAMILY_NAMES).
    pub fn family(&self) -> &'static str {
        match self {
            Kernel::Linear => "linear",
            Kernel::Rbf { .. } => "rbf",
            Kernel::Poly { .. } => "poly",
            Kernel::Sigmoid { .. } => "sigmoid",
        }
    }

    /// (g, c, degree) params vector fed to the PJRT artifacts.
    ///
    /// The PJRT wire format is f32 end to end (artifact inputs, device
    /// buffers), so hyper-parameters are **deliberately truncated**
    /// here: two kernels whose `g` differs only below f32 resolution
    /// produce identical params vectors and identical device results.
    /// That collapse is confined to the PJRT plane — the native engine
    /// evaluates in f64, `Kernel` equality compares full f64 bits, and
    /// snapshot config fingerprints hash the f64 encoding, so two such
    /// models never silently alias outside the accelerator path
    /// (pinned by `params3_truncation_cannot_alias_models`).
    pub fn params3(&self) -> [f32; 3] {
        match *self {
            Kernel::Linear => [0.0, 0.0, 0.0],
            Kernel::Rbf { g } => [g as f32, 0.0, 0.0],
            Kernel::Poly { g, c, degree } => [g as f32, c as f32, degree as f32],
            Kernel::Sigmoid { g, c } => [g as f32, c as f32, 0.0],
        }
    }

    /// Evaluate k(a, b).
    #[inline]
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match *self {
            Kernel::Linear => dot(a, b),
            Kernel::Rbf { g } => (-g * sq_dist(a, b)).exp(),
            Kernel::Poly { g, c, degree } => (g * dot(a, b) + c).powf(degree),
            Kernel::Sigmoid { g, c } => (g * dot(a, b) + c).tanh(),
        }
    }

    /// Evaluate k(a, b) at single precision (f32 contraction + f32
    /// transcendental), widened to f64. See [`Precision::F32`].
    #[inline]
    pub fn eval_f32(&self, a: &[f64], b: &[f64]) -> f64 {
        f64::from(match *self {
            Kernel::Linear => dot_f32(a, b),
            Kernel::Rbf { g } => (-(g as f32) * sq_dist_f32(a, b)).exp(),
            Kernel::Poly { g, c, degree } => {
                (g as f32 * dot_f32(a, b) + c as f32).powf(degree as f32)
            }
            Kernel::Sigmoid { g, c } => (g as f32 * dot_f32(a, b) + c as f32).tanh(),
        })
    }

    /// Evaluate k(a, b) in the given compute mode.
    #[inline]
    pub fn eval_in(&self, prec: Precision, a: &[f64], b: &[f64]) -> f64 {
        match prec {
            Precision::F64 => self.eval(a, b),
            Precision::F32 => self.eval_f32(a, b),
        }
    }

    /// Blocked row fill: `out[k] = k(row, x[j0 + k])`.
    ///
    /// Two passes so each inner loop is a single tight shape the
    /// compiler can vectorize: pass 1 runs the lane-blocked
    /// contraction (`sq_dist`/`dot`) per element, pass 2 applies the
    /// scalar transform (fused exp/powf/tanh batch over the row).
    /// Per element this performs the exact operations of [`eval`] in
    /// the same order, so the result is bitwise identical to the
    /// scalar path — the property the persistence checksums and the
    /// blocked-vs-scalar parity suite rely on.
    fn row_block(&self, x: &Matrix, row: &[f64], out: &mut [f64], j0: usize) {
        debug_assert!(j0 + out.len() <= x.rows());
        match *self {
            Kernel::Linear => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = dot(row, x.row(j0 + k));
                }
            }
            Kernel::Rbf { g } => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = sq_dist(row, x.row(j0 + k));
                }
                for o in out.iter_mut() {
                    *o = (-g * *o).exp();
                }
            }
            Kernel::Poly { g, c, degree } => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = dot(row, x.row(j0 + k));
                }
                for o in out.iter_mut() {
                    *o = (g * *o + c).powf(degree);
                }
            }
            Kernel::Sigmoid { g, c } => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = dot(row, x.row(j0 + k));
                }
                for o in out.iter_mut() {
                    *o = (g * *o + c).tanh();
                }
            }
        }
    }

    /// f32 analogue of [`Self::row_block`]: f32 contraction, fused f32
    /// transform batch, widened into the f64 output row.
    fn row_block_f32(&self, x: &Matrix, row: &[f64], out: &mut [f64], j0: usize) {
        debug_assert!(j0 + out.len() <= x.rows());
        match *self {
            Kernel::Linear => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = f64::from(dot_f32(row, x.row(j0 + k)));
                }
            }
            Kernel::Rbf { g } => {
                let g32 = g as f32;
                for (k, o) in out.iter_mut().enumerate() {
                    *o = f64::from(sq_dist_f32(row, x.row(j0 + k)));
                }
                for o in out.iter_mut() {
                    *o = f64::from((-g32 * *o as f32).exp());
                }
            }
            Kernel::Poly { g, c, degree } => {
                let (g32, c32, d32) = (g as f32, c as f32, degree as f32);
                for (k, o) in out.iter_mut().enumerate() {
                    *o = f64::from(dot_f32(row, x.row(j0 + k)));
                }
                for o in out.iter_mut() {
                    *o = f64::from((g32 * *o as f32 + c32).powf(d32));
                }
            }
            Kernel::Sigmoid { g, c } => {
                let (g32, c32) = (g as f32, c as f32);
                for (k, o) in out.iter_mut().enumerate() {
                    *o = f64::from(dot_f32(row, x.row(j0 + k)));
                }
                for o in out.iter_mut() {
                    *o = f64::from((g32 * *o as f32 + c32).tanh());
                }
            }
        }
    }

    /// Fill `out[j] = k(x_row, x[j])` for all rows j of `x` (blocked).
    pub fn row(&self, x: &Matrix, row: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), x.rows());
        self.row_block(x, row, out, 0);
    }

    /// [`Self::row`] in the given compute mode.
    pub fn row_in(&self, prec: Precision, x: &Matrix, row: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), x.rows());
        match prec {
            Precision::F64 => self.row_block(x, row, out, 0),
            Precision::F32 => self.row_block_f32(x, row, out, 0),
        }
    }

    /// Full Gram matrix, parallel over row blocks, exploiting symmetry.
    pub fn gram(&self, x: &Matrix, threads: usize) -> Matrix {
        self.gram_in(Precision::F64, x, threads)
    }

    /// [`Self::gram`] in the given compute mode. Each worker fills the
    /// upper triangle of its rows through the blocked row path (j >= i);
    /// the mirror pass completes the matrix, so symmetry is exact by
    /// construction in both modes.
    pub fn gram_in(&self, prec: Precision, x: &Matrix, threads: usize) -> Matrix {
        let n = x.rows();
        let mut k = Matrix::zeros(n, n);
        threadpool::parallel_rows(k.data_mut(), n, threads, |start, rows| {
            for (r, out) in rows.chunks_mut(n).enumerate() {
                let i = start + r;
                let xi = x.row(i);
                match prec {
                    Precision::F64 => self.row_block(x, xi, &mut out[i..], i),
                    Precision::F32 => self.row_block_f32(x, xi, &mut out[i..], i),
                }
            }
        });
        // mirror upper -> lower
        for i in 0..n {
            for j in 0..i {
                let v = k.get(j, i);
                k.set(i, j, v);
            }
        }
        k
    }

    /// Cross-kernel matrix K[i][j] = k(x_i, q_j), blocked per row.
    pub fn cross(&self, x: &Matrix, q: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), q.cols());
        let (n, m) = (x.rows(), q.rows());
        let mut k = Matrix::zeros(n, m);
        threadpool::parallel_rows(k.data_mut(), m, threads, |start, rows| {
            for (r, out) in rows.chunks_mut(m).enumerate() {
                let xi = x.row(start + r);
                self.row_block(q, xi, out, 0);
            }
        });
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let data = (0..n * d).map(|_| rng.normal()).collect();
        Matrix::from_vec(n, d, data)
    }

    #[test]
    fn linear_is_dot() {
        let k = Kernel::Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn rbf_properties() {
        let k = Kernel::Rbf { g: 0.5 };
        let a = [1.0, -2.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-12);
        let b = [100.0, 100.0];
        assert!(k.eval(&a, &b) < 1e-10);
        assert!(k.eval(&a, &b) >= 0.0);
    }

    #[test]
    fn poly_matches_formula() {
        let k = Kernel::Poly { g: 2.0, c: 1.0, degree: 3.0 };
        // (2*11 + 1)^3 = 23^3
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 23f64.powi(3)).abs() < 1e-9);
    }

    #[test]
    fn sigmoid_matches_formula() {
        let k = Kernel::Sigmoid { g: 0.1, c: -0.5 };
        let want = (0.1 * 11.0 - 0.5f64).tanh();
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - want).abs() < 1e-12);
    }

    #[test]
    fn gram_symmetric_and_correct() {
        let x = rand_matrix(50, 3, 1);
        for k in [
            Kernel::Linear,
            Kernel::Rbf { g: 0.7 },
            Kernel::Poly { g: 0.5, c: 1.0, degree: 2.0 },
            Kernel::Sigmoid { g: 0.2, c: 0.1 },
        ] {
            let g = k.gram(&x, 4);
            for i in 0..50 {
                for j in 0..50 {
                    assert!(
                        (g.get(i, j) - k.eval(x.row(i), x.row(j))).abs() < 1e-12,
                        "mismatch at ({i},{j}) for {k:?}"
                    );
                    assert_eq!(g.get(i, j), g.get(j, i));
                }
            }
        }
    }

    #[test]
    fn gram_thread_invariance() {
        let x = rand_matrix(64, 4, 2);
        let k = Kernel::Rbf { g: 0.3 };
        let g1 = k.gram(&x, 1);
        let g8 = k.gram(&x, 8);
        assert_eq!(g1.data(), g8.data());
    }

    #[test]
    fn cross_matches_eval() {
        let x = rand_matrix(20, 3, 3);
        let q = rand_matrix(7, 3, 4);
        let k = Kernel::Rbf { g: 1.1 };
        let c = k.cross(&x, &q, 3);
        for i in 0..20 {
            for j in 0..7 {
                assert!((c.get(i, j) - k.eval(x.row(i), q.row(j))).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn row_matches_gram() {
        let x = rand_matrix(30, 2, 5);
        let k = Kernel::Linear;
        let g = k.gram(&x, 2);
        let mut row = vec![0.0; 30];
        k.row(&x, x.row(17), &mut row);
        for j in 0..30 {
            assert_eq!(row[j], g.get(17, j));
        }
    }

    #[test]
    fn params3_layout() {
        assert_eq!(Kernel::Rbf { g: 0.5 }.params3(), [0.5, 0.0, 0.0]);
        assert_eq!(
            Kernel::Poly { g: 1.0, c: 2.0, degree: 3.0 }.params3(),
            [1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn params3_truncation_cannot_alias_models() {
        // γ split below f32 resolution: the PJRT params vector collapses
        // (documented truncation) but the native-side identities stay
        // distinct, so no silent model aliasing outside the device path.
        let g = 0.5f64;
        let g_eps = f64::from(0.5f32) + 1e-12;
        assert_ne!(g.to_bits(), g_eps.to_bits());
        let (ka, kb) = (Kernel::Rbf { g }, Kernel::Rbf { g: g_eps });
        assert_eq!(ka.params3(), kb.params3(), "f32 wire collapse is expected");
        assert_ne!(ka, kb, "native identity must keep full f64 bits");
        // and the native engine actually computes different values
        let a = [1.0, 2.0, 3.0];
        let b = [0.0, 1.0, -1.0];
        assert_ne!(ka.eval(&a, &b).to_bits(), kb.eval(&a, &b).to_bits());
    }

    #[test]
    fn blocked_row_bitwise_matches_scalar_eval() {
        let x = rand_matrix(41, 7, 9); // odd sizes exercise lane tails
        for k in [
            Kernel::Linear,
            Kernel::Rbf { g: 0.7 },
            Kernel::Poly { g: 0.5, c: 1.0, degree: 2.0 },
            Kernel::Sigmoid { g: 0.2, c: 0.1 },
        ] {
            let mut row = vec![0.0; 41];
            k.row(&x, x.row(13), &mut row);
            for j in 0..41 {
                assert_eq!(
                    row[j].to_bits(),
                    k.eval(x.row(13), x.row(j)).to_bits(),
                    "blocked row diverged from scalar eval at j={j} for {k:?}"
                );
            }
        }
    }

    #[test]
    fn f32_mode_tracks_f64_and_is_symmetric() {
        let x = rand_matrix(32, 5, 11);
        let k = Kernel::Rbf { g: 0.4 };
        let g64 = k.gram_in(Precision::F64, &x, 3);
        let g32 = k.gram_in(Precision::F32, &x, 3);
        for i in 0..32 {
            for j in 0..32 {
                assert!((g64.get(i, j) - g32.get(i, j)).abs() < 1e-4);
                assert_eq!(g32.get(i, j), g32.get(j, i));
            }
        }
        assert_eq!(
            k.eval_in(Precision::F32, x.row(0), x.row(1)),
            k.eval_f32(x.row(0), x.row(1))
        );
    }

    #[test]
    fn gram_in_f32_thread_invariance() {
        let x = rand_matrix(48, 4, 12);
        let k = Kernel::Poly { g: 0.3, c: 0.5, degree: 2.0 };
        let g1 = k.gram_in(Precision::F32, &x, 1);
        let g8 = k.gram_in(Precision::F32, &x, 8);
        assert_eq!(g1.data(), g8.data());
    }
}
