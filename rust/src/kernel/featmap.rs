//! Explicit feature maps for approximate kernel training (DESIGN.md
//! §10 "Approximate engines").
//!
//! Both maps lift a point `x ∈ R^d` to `φ(x) ∈ R^D` such that the
//! lifted inner product approximates the kernel:
//! `⟨φ(x), φ(y)⟩ ≈ k(x, y)`. Training the slab with a *linear* kernel
//! on lifted features then approximates the exact kernel slab, scoring
//! becomes one D-dimensional dot product independent of the number of
//! support vectors, and incremental absorbs become O(d·D) primal
//! updates instead of O(m) Gram rows.
//!
//! - [`NystroemMap`]: `φ(x) = W^{-1/2} · [k(x, l_1) … k(x, l_L)]ᵀ`
//!   over L landmark points, with `W^{-1/2}` the symmetric pseudo
//!   inverse square root of the landmark Gram via
//!   [`crate::linalg::sym_eig`]. Exact (rank-limited) when landmarks
//!   cover the data; works for every kernel family.
//! - [`RffMap`]: random Fourier features for [`Kernel::Rbf`] only —
//!   an unbiased Monte-Carlo estimator of the RBF kernel with
//!   O(1/√D) error, deterministic by seed (Bochner's theorem: the
//!   Fourier transform of `exp(-g‖δ‖²)` is Gaussian with variance
//!   `2g` per coordinate).
//!
//! Everything here is availability-critical (slablint R1 scope: no
//! panics, no unchecked indexing) and the per-point mapping paths are
//! allocation-free (R3 hot scope): callers own the grow-once scratch.

use crate::error::{Error, Result};
use crate::kernel::Kernel;
use crate::linalg::{dot, sym_eig, Matrix};
use crate::util::rng::Rng;
use std::fmt;
use std::str::FromStr;

/// Which solving engine a trainer / stream uses (DESIGN.md §10).
///
/// `Exact` is the reference path (full Gram, SMO family). The other
/// two select the approximate feature-map engine with the named map.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Exact kernel solve (full Gram; the paper's algorithm).
    #[default]
    Exact,
    /// Nyström landmark feature map + linear slab in lifted space.
    Nystroem,
    /// Random Fourier features (RBF only) + linear slab in lifted
    /// space.
    Rff,
}

impl EngineKind {
    /// Every engine, for parameterized tests and CLI listings.
    pub const ALL: [EngineKind; 3] =
        [EngineKind::Exact, EngineKind::Nystroem, EngineKind::Rff];

    /// Stable CLI / snapshot name.
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Exact => "exact",
            EngineKind::Nystroem => "nystroem",
            EngineKind::Rff => "rff",
        }
    }

    /// Stable one-byte tag for the snapshot format (v3+).
    pub fn tag(&self) -> u8 {
        match self {
            EngineKind::Exact => 0,
            EngineKind::Nystroem => 1,
            EngineKind::Rff => 2,
        }
    }

    /// Inverse of [`EngineKind::tag`] for snapshot decode.
    pub fn from_tag(t: u8) -> Result<EngineKind> {
        match t {
            0 => Ok(EngineKind::Exact),
            1 => Ok(EngineKind::Nystroem),
            2 => Ok(EngineKind::Rff),
            other => Err(Error::snapshot(format!("unknown engine tag {other}"))),
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for EngineKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        match s {
            "exact" => Ok(EngineKind::Exact),
            "nystroem" | "nystrom" => Ok(EngineKind::Nystroem),
            "rff" | "fourier" => Ok(EngineKind::Rff),
            other => Err(Error::config(format!(
                "unknown engine {other:?} (expected exact|nystroem|rff)"
            ))),
        }
    }
}

/// An explicit feature map `φ: R^{d_in} → R^{d_out}` with
/// `⟨φ(x), φ(y)⟩ ≈ k(x, y)`.
///
/// Contract (pinned by `rust/tests/featmap.rs`):
/// - **Deterministic**: the same map applied to the same bytes
///   produces the same bytes, independent of thread count (no
///   internal state, no parallelism, no ambient randomness).
/// - **Allocation-free mapping**: [`map_into`](Self::map_into) and
///   [`dot_lifted`](Self::dot_lifted) never allocate; callers pass a
///   scratch slice of [`scratch_len`](Self::scratch_len) elements.
/// - `dot_lifted(x, v)` equals `⟨v, φ(x)⟩` up to floating-point
///   reassociation — it exists so scoring never materializes `φ(x)`.
pub trait FeatureMap {
    /// Input dimension d.
    fn d_in(&self) -> usize;

    /// Lifted dimension D.
    fn d_out(&self) -> usize;

    /// Required scratch length for [`map_into`](Self::map_into)
    /// (0 when the map needs none).
    fn scratch_len(&self) -> usize;

    /// Write `φ(x)` into `out` (`out.len() == d_out()`), using
    /// caller-owned `scratch` (`scratch.len() >= scratch_len()`).
    fn map_into(&self, x: &[f64], scratch: &mut [f64], out: &mut [f64]);

    /// `⟨v, φ(x)⟩` without materializing `φ(x)` — the O(SV-free)
    /// scoring primitive. `v.len() == d_out()`.
    fn dot_lifted(&self, x: &[f64], v: &[f64]) -> f64;

    /// Map every row of `x` (allocating; batch-fit setup path).
    fn map_rows(&self, x: &Matrix) -> Matrix {
        let mut scratch = vec![0.0; self.scratch_len()];
        let mut out = Matrix::zeros(x.rows(), self.d_out());
        for i in 0..x.rows() {
            self.map_into(x.row(i), &mut scratch, out.row_mut(i));
        }
        out
    }
}

// ---------------------------------------------------------- RFF

/// Random Fourier features for the RBF kernel
/// `k(x,y) = exp(-g‖x-y‖²)`.
///
/// Draws `P = d_out/2` frequency rows `ω_p ~ N(0, 2g·I)` from a
/// seeded [`Rng`] and maps
/// `φ(x) = √(1/P) · [cos(ω_1ᵀx), sin(ω_1ᵀx), …, cos(ω_Pᵀx), sin(ω_Pᵀx)]`,
/// so `E[⟨φ(x), φ(y)⟩] = exp(-g‖x-y‖²)` exactly (unbiased), with
/// Monte-Carlo error O(1/√P). Fully reconstructible from
/// `(d_in, d_out, g, seed)` — snapshots persist only those four
/// numbers.
#[derive(Clone, Debug)]
pub struct RffMap {
    freqs: Matrix,
    g: f64,
    seed: u64,
    scale: f64,
}

impl RffMap {
    /// Build a map with `d_out` features (must be even and ≥ 2) for
    /// RBF bandwidth `g > 0`.
    pub fn new(d_in: usize, d_out: usize, g: f64, seed: u64) -> Result<RffMap> {
        if d_in == 0 {
            return Err(Error::config("rff: input dimension must be >= 1"));
        }
        if d_out < 2 || d_out % 2 != 0 {
            return Err(Error::config(format!(
                "rff: feature count must be even and >= 2, got {d_out}"
            )));
        }
        if !(g > 0.0) || !g.is_finite() {
            return Err(Error::config(format!(
                "rff: rbf bandwidth g must be finite and > 0, got {g}"
            )));
        }
        let pairs = d_out / 2;
        let sd = (2.0 * g).sqrt();
        let mut rng = Rng::new(seed);
        let data = (0..pairs * d_in)
            .map(|_| rng.normal_ms(0.0, sd))
            .collect();
        Ok(RffMap {
            freqs: Matrix::from_vec(pairs, d_in, data),
            g,
            seed,
            scale: (1.0 / pairs as f64).sqrt(),
        })
    }

    /// RBF bandwidth this map approximates.
    pub fn g(&self) -> f64 {
        self.g
    }

    /// Seed the frequency matrix was drawn from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Hot mapping body (slablint R3: allocation-free).
    fn fourier_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), 2 * self.freqs.rows());
        for (p, pair) in out.chunks_exact_mut(2).enumerate() {
            let t = dot(self.freqs.row(p), x);
            if let [oc, os] = pair {
                *oc = self.scale * t.cos();
                *os = self.scale * t.sin();
            }
        }
    }

    /// Hot scoring body: `⟨v, φ(x)⟩` accumulated per frequency pair
    /// (slablint R3: allocation-free, scratch-free).
    fn fourier_dot(&self, x: &[f64], v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), 2 * self.freqs.rows());
        let mut acc = 0.0;
        for (p, pair) in v.chunks_exact(2).enumerate() {
            let t = dot(self.freqs.row(p), x);
            if let [vc, vs] = pair {
                acc += vc * t.cos() + vs * t.sin();
            }
        }
        acc * self.scale
    }
}

impl FeatureMap for RffMap {
    fn d_in(&self) -> usize {
        self.freqs.cols()
    }

    fn d_out(&self) -> usize {
        2 * self.freqs.rows()
    }

    fn scratch_len(&self) -> usize {
        0
    }

    fn map_into(&self, x: &[f64], _scratch: &mut [f64], out: &mut [f64]) {
        self.fourier_into(x, out);
    }

    fn dot_lifted(&self, x: &[f64], v: &[f64]) -> f64 {
        self.fourier_dot(x, v)
    }
}

// ------------------------------------------------------ Nyström

/// Nyström landmark feature map
/// `φ(x) = W^{-1/2} · [k(x, l_1) … k(x, l_L)]ᵀ`.
///
/// `W` is the L×L landmark Gram and `W^{-1/2}` its symmetric pseudo
/// inverse square root: eigenvalues at or below `1e-12·λ_max` are
/// treated as exactly zero (pseudo-inverse semantics), so a rank
/// deficient landmark set degrades to its numerical rank instead of
/// exploding. When the landmarks are the full dataset the lifted
/// Gram `ΦΦᵀ = K W⁺ K` reproduces `K` exactly on its range — the
/// ≤1e-9 parity pinned by `rust/tests/featmap.rs`. Works for every
/// kernel family (the map evaluates `k` directly).
#[derive(Clone, Debug)]
pub struct NystroemMap {
    kernel: Kernel,
    landmarks: Matrix,
    wihalf: Matrix,
}

impl NystroemMap {
    /// Build the map from an explicit landmark matrix (L×d, L ≥ 1).
    ///
    /// Deterministic and single-threaded: the landmark Gram, the
    /// Jacobi eigendecomposition and the `W^{-1/2}` assembly are all
    /// fixed-order f64 loops, so the same landmark bytes always
    /// produce the same map bytes (snapshot restore relies on this).
    pub fn new(kernel: Kernel, landmarks: Matrix) -> Result<NystroemMap> {
        let l = landmarks.rows();
        if l == 0 {
            return Err(Error::config("nystroem: need at least one landmark"));
        }
        let w = kernel.gram(&landmarks, 1);
        let (evals, v) = sym_eig(&w);
        let lmax = evals.iter().fold(0.0_f64, |m, &e| m.max(e));
        let floor = 1e-12 * lmax.max(f64::MIN_POSITIVE);
        let inv_sqrt: Vec<f64> = evals
            .iter()
            .map(|&e| if e > floor { 1.0 / e.sqrt() } else { 0.0 })
            .collect();
        let mut wihalf = Matrix::zeros(l, l);
        for i in 0..l {
            for j in 0..=i {
                let mut acc = 0.0;
                for (k, s) in inv_sqrt.iter().enumerate() {
                    acc += v.get(i, k) * s * v.get(j, k);
                }
                wihalf.set(i, j, acc);
                wihalf.set(j, i, acc);
            }
        }
        Ok(NystroemMap { kernel, landmarks, wihalf })
    }

    /// The kernel this map approximates.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Landmark matrix (L×d).
    pub fn landmarks(&self) -> &Matrix {
        &self.landmarks
    }

    /// `W^{-1/2}` (symmetric, L×L) — the fold-back operator that turns
    /// a lifted weight vector into plain kernel coefficients on the
    /// landmarks: `s(x) = ⟨w, φ(x)⟩ = ⟨W^{-1/2}w, k_L(x)⟩`.
    pub fn wihalf(&self) -> &Matrix {
        &self.wihalf
    }

    /// Hot mapping body: landmark kernel row into `scratch`, then
    /// `out = W^{-1/2}·scratch` (slablint R3: allocation-free).
    fn landmark_into(&self, x: &[f64], scratch: &mut [f64], out: &mut [f64]) {
        debug_assert_eq!(scratch.len(), self.landmarks.rows());
        debug_assert_eq!(out.len(), self.landmarks.rows());
        self.kernel.row(&self.landmarks, x, scratch);
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot(self.wihalf.row(i), scratch);
        }
    }

    /// Hot scoring body: `⟨v, φ(x)⟩ = Σ_j k(l_j, x) · ⟨v, W^{-1/2}_{·j}⟩`
    /// using the symmetry of `W^{-1/2}` (column j = row j) — O(L·d + L²)
    /// with no scratch (slablint R3: allocation-free).
    fn landmark_dot(&self, x: &[f64], v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.landmarks.rows());
        let mut acc = 0.0;
        for j in 0..self.landmarks.rows() {
            let klj = self.kernel.eval(self.landmarks.row(j), x);
            acc += klj * dot(v, self.wihalf.row(j));
        }
        acc
    }
}

impl FeatureMap for NystroemMap {
    fn d_in(&self) -> usize {
        self.landmarks.cols()
    }

    fn d_out(&self) -> usize {
        self.landmarks.rows()
    }

    fn scratch_len(&self) -> usize {
        self.landmarks.rows()
    }

    fn map_into(&self, x: &[f64], scratch: &mut [f64], out: &mut [f64]) {
        self.landmark_into(x, scratch, out);
    }

    fn dot_lifted(&self, x: &[f64], v: &[f64]) -> f64 {
        self.landmark_dot(x, v)
    }
}

// ------------------------------------------------------ enum sum

/// Runtime-selected feature map (the concrete type behind an
/// [`EngineKind`] choice), so stream/solver state can hold either map
/// without generics bleeding through the session layer.
#[derive(Clone, Debug)]
pub enum FeatMap {
    /// Nyström landmark map.
    Nystroem(NystroemMap),
    /// Random Fourier feature map.
    Rff(RffMap),
}

impl FeatMap {
    /// Which engine family this map belongs to.
    pub fn engine_kind(&self) -> EngineKind {
        match self {
            FeatMap::Nystroem(_) => EngineKind::Nystroem,
            FeatMap::Rff(_) => EngineKind::Rff,
        }
    }

    /// Downcast to the Nyström map (snapshot encode path).
    pub fn as_nystroem(&self) -> Option<&NystroemMap> {
        match self {
            FeatMap::Nystroem(m) => Some(m),
            FeatMap::Rff(_) => None,
        }
    }

    /// Downcast to the RFF map (snapshot encode / model JSON path).
    pub fn as_rff(&self) -> Option<&RffMap> {
        match self {
            FeatMap::Nystroem(_) => None,
            FeatMap::Rff(m) => Some(m),
        }
    }
}

impl FeatureMap for FeatMap {
    fn d_in(&self) -> usize {
        match self {
            FeatMap::Nystroem(m) => m.d_in(),
            FeatMap::Rff(m) => m.d_in(),
        }
    }

    fn d_out(&self) -> usize {
        match self {
            FeatMap::Nystroem(m) => m.d_out(),
            FeatMap::Rff(m) => m.d_out(),
        }
    }

    fn scratch_len(&self) -> usize {
        match self {
            FeatMap::Nystroem(m) => m.scratch_len(),
            FeatMap::Rff(m) => m.scratch_len(),
        }
    }

    fn map_into(&self, x: &[f64], scratch: &mut [f64], out: &mut [f64]) {
        match self {
            FeatMap::Nystroem(m) => m.map_into(x, scratch, out),
            FeatMap::Rff(m) => m.map_into(x, scratch, out),
        }
    }

    fn dot_lifted(&self, x: &[f64], v: &[f64]) -> f64 {
        match self {
            FeatMap::Nystroem(m) => m.dot_lifted(x, v),
            FeatMap::Rff(m) => m.dot_lifted(x, v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let data = (0..n * d).map(|_| rng.normal()).collect();
        Matrix::from_vec(n, d, data)
    }

    #[test]
    fn engine_kind_roundtrips() {
        for k in EngineKind::ALL {
            assert_eq!(k.name().parse::<EngineKind>().unwrap(), k);
            assert_eq!(EngineKind::from_tag(k.tag()).unwrap(), k);
            assert_eq!(format!("{k}"), k.name());
        }
        assert_eq!("nystrom".parse::<EngineKind>().unwrap(), EngineKind::Nystroem);
        assert!("bogus".parse::<EngineKind>().is_err());
        assert!(EngineKind::from_tag(9).is_err());
        assert_eq!(EngineKind::default(), EngineKind::Exact);
    }

    #[test]
    fn rff_new_validates() {
        assert!(RffMap::new(0, 4, 0.5, 1).is_err());
        assert!(RffMap::new(3, 3, 0.5, 1).is_err()); // odd
        assert!(RffMap::new(3, 0, 0.5, 1).is_err());
        assert!(RffMap::new(3, 4, 0.0, 1).is_err());
        assert!(RffMap::new(3, 4, f64::NAN, 1).is_err());
        let m = RffMap::new(3, 8, 0.5, 1).unwrap();
        assert_eq!(m.d_in(), 3);
        assert_eq!(m.d_out(), 8);
        assert_eq!(m.scratch_len(), 0);
    }

    #[test]
    fn rff_dot_lifted_matches_materialized() {
        let m = RffMap::new(4, 16, 0.3, 7).unwrap();
        let x = rand_matrix(5, 4, 11);
        let mut rng = Rng::new(13);
        let v: Vec<f64> = (0..16).map(|_| rng.normal()).collect();
        let phi = m.map_rows(&x);
        for i in 0..5 {
            let want = dot(phi.row(i), &v);
            let got = m.dot_lifted(x.row(i), &v);
            assert!((want - got).abs() < 1e-12, "row {i}: {want} vs {got}");
        }
    }

    #[test]
    fn rff_bitwise_deterministic_by_seed() {
        let a = RffMap::new(3, 32, 0.7, 42).unwrap();
        let b = RffMap::new(3, 32, 0.7, 42).unwrap();
        let c = RffMap::new(3, 32, 0.7, 43).unwrap();
        let x = rand_matrix(4, 3, 5);
        let (pa, pb, pc) = (a.map_rows(&x), b.map_rows(&x), c.map_rows(&x));
        assert_eq!(pa.data(), pb.data(), "same seed must be bitwise equal");
        assert_ne!(pa.data(), pc.data(), "different seed must differ");
    }

    #[test]
    fn rff_unit_norm_in_expectation() {
        // ⟨φ(x), φ(x)⟩ = (1/P)·Σ (cos² + sin²) = 1 exactly, per point.
        let m = RffMap::new(2, 64, 1.1, 3).unwrap();
        let x = rand_matrix(3, 2, 9);
        let phi = m.map_rows(&x);
        for i in 0..3 {
            assert!((dot(phi.row(i), phi.row(i)) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn nystroem_exact_at_full_landmarks() {
        let x = rand_matrix(20, 3, 17);
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.4 }] {
            let m = NystroemMap::new(kernel, x.clone()).unwrap();
            let phi = m.map_rows(&x);
            for i in 0..20 {
                for j in 0..20 {
                    let approx = dot(phi.row(i), phi.row(j));
                    let exact = kernel.eval(x.row(i), x.row(j));
                    assert!(
                        (approx - exact).abs() < 1e-9,
                        "({i},{j}) {kernel:?}: {approx} vs {exact}"
                    );
                }
            }
        }
    }

    #[test]
    fn nystroem_dot_lifted_matches_materialized() {
        let x = rand_matrix(12, 3, 19);
        let landmarks = x.select_rows(&[0, 2, 4, 6, 8]);
        let m = NystroemMap::new(Kernel::Rbf { g: 0.6 }, landmarks).unwrap();
        let mut rng = Rng::new(23);
        let v: Vec<f64> = (0..m.d_out()).map(|_| rng.normal()).collect();
        let phi = m.map_rows(&x);
        for i in 0..12 {
            let want = dot(phi.row(i), &v);
            let got = m.dot_lifted(x.row(i), &v);
            assert!((want - got).abs() < 1e-10, "row {i}: {want} vs {got}");
        }
    }

    #[test]
    fn nystroem_rank_deficient_landmarks_stay_finite() {
        // duplicated landmarks -> singular W; the eigenvalue floor must
        // keep the map finite (pseudo-inverse, not a blow-up)
        let base = rand_matrix(4, 2, 29);
        let landmarks = base.select_rows(&[0, 0, 1, 1, 2, 3]);
        let m = NystroemMap::new(Kernel::Linear, landmarks).unwrap();
        let phi = m.map_rows(&base);
        assert!(phi.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn featmap_enum_delegates() {
        let x = rand_matrix(6, 3, 31);
        let nys = FeatMap::Nystroem(
            NystroemMap::new(Kernel::Rbf { g: 0.5 }, x.select_rows(&[0, 1, 2])).unwrap(),
        );
        let rff = FeatMap::Rff(RffMap::new(3, 8, 0.5, 7).unwrap());
        assert_eq!(nys.engine_kind(), EngineKind::Nystroem);
        assert_eq!(rff.engine_kind(), EngineKind::Rff);
        assert!(nys.as_nystroem().is_some() && nys.as_rff().is_none());
        assert!(rff.as_rff().is_some() && rff.as_nystroem().is_none());
        for map in [&nys, &rff] {
            let mut scratch = vec![0.0; map.scratch_len()];
            let mut out = vec![0.0; map.d_out()];
            map.map_into(x.row(4), &mut scratch, &mut out);
            let got = map.dot_lifted(x.row(4), &out);
            assert!((got - dot(&out, &out)).abs() < 1e-10);
        }
    }
}
