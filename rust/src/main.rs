//! `slabsvm` — CLI for the OCSSVM-SMO stack.
//!
//! Subcommands:
//!   train     train a model on a CSV/libsvm/synthetic dataset, save JSON
//!   predict   score a CSV of query points with a saved model
//!   eval      evaluate a saved model on a labeled dataset (MCC etc.)
//!   figures   regenerate the paper's Fig. 1 / Fig. 2 (CSV + SVG)
//!   bench     print paper tables: table1 | qp | heuristics
//!   serve     HTTP/1.1 front door: score / stream-push / forget /
//!             snapshot / metrics / trace as endpoints, with
//!             bearer-token auth, rate limiting and 429/stale-model
//!             admission control (DESIGN.md §9)
//!   stream    online learning on drifting streams; --restore-dir
//!             resumes a snapshotted fleet, --snapshot-dir /
//!             --checkpoint-dir persist it, --evict picks the
//!             window-eviction policy
//!   snapshot  write durable stream snapshots (or --inspect one)
//!   forget    targeted unlearning: remove samples by id from a
//!             stream snapshot, repair, write it back
//!   stats     drive a short traced workload, print every service
//!             metric (Prometheus text or JSON lines)
//!   trace     drive a short traced workload, print the span chains
//!             and (--events) the flight-recorder events as JSONL
//!   info      artifact manifest + engine diagnostics
//!
//! Run `slabsvm <cmd> --help` for per-command options.

use std::process::ExitCode;

use slabsvm::config::{parse_heuristic, parse_kernel};
use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::loaders::{load_csv, load_libsvm, CsvOptions};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::data::Dataset;
use slabsvm::error::Error;
use slabsvm::kernel::Kernel;
use slabsvm::metrics::roc_auc;
use slabsvm::runtime::Engine;
use slabsvm::solver::api::{SolverKind, Trainer};
use slabsvm::solver::ocssvm::SlabModel;
use slabsvm::util::cli::{parse_args, render_help, ArgSpec, Parsed};
use slabsvm::util::logging;
use slabsvm::Result;

fn main() -> ExitCode {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "predict" => cmd_predict(rest),
        "eval" => cmd_eval(rest),
        "figures" => cmd_figures(rest),
        "bench" => cmd_bench(rest),
        "serve" => cmd_serve(rest),
        "stream" => cmd_stream(rest),
        "snapshot" => cmd_snapshot(rest),
        "forget" => cmd_forget(rest),
        "sweep" => cmd_sweep(rest),
        "stats" => cmd_stats(rest),
        "trace" => cmd_trace(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(Error::config(format!("unknown subcommand {other}"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "slabsvm — SMO for One-Class Slab SVMs (paper reproduction)\n\n\
     subcommands:\n\
     \ttrain    train a model and save it as JSON\n\
     \tpredict  score query points with a saved model\n\
     \teval     evaluate a saved model on labeled data (MCC, F1, AUC)\n\
     \tfigures  regenerate paper Fig. 1 / Fig. 2 (CSV + SVG)\n\
     \tbench    print paper tables: --which table1|qp|heuristics\n\
     \tserve    HTTP/1.1 front door for scoring + tenant streams (--addr, --auth, --rate)\n\
     \tstream   online learning over synthetic drifting streams (--streams M = sharded multi-tenant)\n\
     \tsnapshot write durable stream snapshots from a synthetic fleet, or --inspect one\n\
     \tforget   targeted unlearning: remove samples by id from a snapshot, repair, write back\n\
     \tsweep    k-fold cross-validated hyper-parameter grid search\n\
     \tstats    traced synthetic workload → metrics export (--format prom|json)\n\
     \ttrace    traced synthetic workload → span chains + flight-recorder events (JSONL)\n\
     \tinfo     artifact manifest + engine diagnostics\n"
        .to_string()
}

// ------------------------------------------------------------------ common

fn kernel_args() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("kernel", "linear", "kernel family: linear|rbf|poly|sigmoid"),
        ArgSpec::opt("gamma", "1.0", "kernel g parameter"),
        ArgSpec::opt("coef0", "0.0", "kernel c parameter"),
        ArgSpec::opt("degree", "3.0", "poly degree"),
    ]
}

fn solver_args() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("solver", "smo", "solver: smo|pg|ipm|ocsvm-smo|approx"),
        ArgSpec::opt(
            "engine",
            "exact",
            "training engine: exact|nystroem|rff (approx feature-map solve)",
        ),
        ArgSpec::opt(
            "features",
            "64",
            "lifted feature budget for --engine nystroem|rff",
        ),
        ArgSpec::opt("nu1", "0.5", "nu1 (lower-plane outlier bound; OCSVM nu)"),
        ArgSpec::opt("nu2", "0.01", "nu2 (upper-plane violator bound)"),
        ArgSpec::opt("eps", "0.6666666666666666", "eps (upper-plane mass)"),
        ArgSpec::opt("tol", "", "convergence tolerance (empty = per-solver default)"),
        ArgSpec::opt("max-iter", "", "iteration budget (empty = per-solver default)"),
        ArgSpec::opt(
            "heuristic",
            "paper-max-fbar",
            "SMO working-set rule: paper-max-fbar|max-violation|random-violator|second-order",
        ),
    ]
}

fn data_args() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("data", "synthetic:slab", "CSV/libsvm path or synthetic:slab"),
        ArgSpec::opt("size", "1000", "synthetic dataset size"),
        ArgSpec::opt("seed", "42", "synthetic dataset seed"),
        ArgSpec::flag("labeled", "CSV has a trailing +1/-1 label column"),
        ArgSpec::flag("header", "CSV has a header row"),
    ]
}

fn parse_kernel_from(p: &Parsed) -> Result<Kernel> {
    parse_kernel(
        p.get_str("kernel")?,
        p.get_f64("gamma")?,
        p.get_f64("coef0")?,
        p.get_f64("degree")?,
    )
}

fn parse_trainer_from(p: &Parsed, kernel: Kernel) -> Result<Trainer> {
    let kind: SolverKind = p.get_str("solver")?.parse()?;
    let mut t = Trainer::new(kind)
        .kernel(kernel)
        .nu1(p.get_f64("nu1")?)
        .nu2(p.get_f64("nu2")?)
        .eps(p.get_f64("eps")?)
        .heuristic(parse_heuristic(p.get_str("heuristic")?)?);
    let tol = p.get_str("tol")?;
    if !tol.is_empty() {
        t = t.tol(tol.parse::<f64>().map_err(|_| {
            Error::config(format!("--tol: not a number: {tol}"))
        })?);
    }
    let max_iter = p.get_str("max-iter")?;
    if !max_iter.is_empty() {
        t = t.max_iter(max_iter.parse::<usize>().map_err(|_| {
            Error::config(format!("--max-iter: not an integer: {max_iter}"))
        })?);
    }
    let engine: slabsvm::kernel::featmap::EngineKind =
        p.get_str("engine")?.parse()?;
    // `--solver approx` alone keeps its default map; an explicit
    // non-exact engine switches any solver onto the approx path
    if engine != slabsvm::kernel::featmap::EngineKind::Exact {
        t = t.engine(engine);
    }
    t = t.features(p.get_usize("features")?);
    Ok(t)
}

fn load_dataset(p: &Parsed) -> Result<Dataset> {
    let spec = p.get_str("data")?;
    if let Some(kind) = spec.strip_prefix("synthetic:") {
        let size = p.get_usize("size")?;
        let seed = p.get_usize("seed")? as u64;
        return match kind {
            "slab" => Ok(SlabConfig::default().generate(size, seed)),
            "slab-eval" => {
                Ok(SlabConfig::default().generate_eval(size / 2, size / 2, seed))
            }
            other => Err(Error::config(format!("unknown synthetic kind {other}"))),
        };
    }
    if spec.ends_with(".libsvm") || spec.ends_with(".svm") {
        load_libsvm(spec, 0)
    } else {
        load_csv(
            spec,
            CsvOptions { header: p.flag("header"), labeled: p.flag("labeled") },
        )
    }
}

// ------------------------------------------------------------------- train

fn cmd_train(args: &[String]) -> Result<()> {
    let mut spec = vec![ArgSpec::opt("out", "model.json", "output model path")];
    spec.extend(data_args());
    spec.extend(kernel_args());
    spec.extend(solver_args());
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help("train", "train a one-class model (any solver)", &spec)
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let ds = load_dataset(&p)?.positives_only();
    let kernel = parse_kernel_from(&p)?;
    let trainer = parse_trainer_from(&p, kernel)?;
    println!(
        "training on {} points (d={}) solver={} kernel={} nu1={} nu2={} eps={}",
        ds.len(),
        ds.dim(),
        trainer.kind(),
        kernel.family(),
        p.get_f64("nu1")?,
        p.get_f64("nu2")?,
        p.get_f64("eps")?
    );
    let report = trainer.fit(&ds.x)?;
    println!(
        "done: {} iterations in {:.3}s, {} SVs, rho1={:.6} rho2={:.6}, \
         max KKT violation {:.3e}",
        report.stats.iterations,
        report.stats.seconds,
        report.model.n_sv(),
        report.model.rho1,
        report.model.rho2,
        report.certificate.max_kkt_violation,
    );
    let out_path = p.get_str("out")?;
    report.model.save(out_path)?;
    println!("model saved to {out_path}");
    Ok(())
}

// ----------------------------------------------------------------- predict

fn cmd_predict(args: &[String]) -> Result<()> {
    let spec = vec![
        ArgSpec::req("model", "path to a saved model JSON"),
        ArgSpec::req("queries", "CSV of query points (no labels)"),
        ArgSpec::opt("engine", "native", "compute engine: native|pjrt"),
        ArgSpec::opt("artifacts", "artifacts", "artifacts dir for --engine pjrt"),
        ArgSpec::flag("header", "CSV has a header row"),
        ArgSpec::flag("scores", "print raw scores instead of labels"),
    ];
    if args.iter().any(|a| a == "--help") {
        println!("{}", render_help("predict", "score query points", &spec));
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let model = std::sync::Arc::new(SlabModel::load(p.get_str("model")?)?);
    let q = load_csv(
        p.get_str("queries")?,
        CsvOptions { header: p.flag("header"), labeled: false },
    )?;
    let engine = make_engine(&p)?;
    let (scores, labels) = engine.predict(&model, &q.x)?;
    for i in 0..labels.len() {
        if p.flag("scores") {
            println!("{}\t{}", scores[i], labels[i]);
        } else {
            println!("{}", labels[i]);
        }
    }
    Ok(())
}

fn make_engine(p: &Parsed) -> Result<Engine> {
    match p.get("engine").unwrap_or("native") {
        "native" => Ok(Engine::Native),
        "pjrt" => Engine::pjrt(p.get("artifacts").unwrap_or("artifacts")),
        other => Err(Error::config(format!("unknown engine {other}"))),
    }
}

// -------------------------------------------------------------------- eval

fn cmd_eval(args: &[String]) -> Result<()> {
    let mut spec = vec![ArgSpec::req("model", "path to a saved model JSON")];
    spec.extend(data_args());
    if args.iter().any(|a| a == "--help") {
        println!("{}", render_help("eval", "evaluate on labeled data", &spec));
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let model = SlabModel::load(p.get_str("model")?)?;
    let mut ds = load_dataset(&p)?;
    if p.get_str("data")? == "synthetic:slab" {
        // default eval set: half positives, half negatives
        let size = p.get_usize("size")?;
        let seed = p.get_usize("seed")? as u64;
        ds = SlabConfig::default().generate_eval(size / 2, size / 2, seed);
    }
    let c = model.evaluate(&ds);
    let margins: Vec<f64> =
        (0..ds.len()).map(|i| model.margin(ds.x.row(i))).collect();
    println!(
        "n={} tp={} tn={} fp={} fn={}",
        ds.len(),
        c.tp,
        c.tn,
        c.fp,
        c.fn_
    );
    println!(
        "accuracy={:.4} precision={:.4} recall={:.4} f1={:.4} mcc={:.4} auc={:.4}",
        c.accuracy(),
        c.precision(),
        c.recall(),
        c.f1(),
        c.mcc(),
        roc_auc(&ds.y, &margins)
    );
    Ok(())
}

// ----------------------------------------------------------------- figures

fn cmd_figures(args: &[String]) -> Result<()> {
    let spec = vec![
        ArgSpec::opt("fig", "1", "which figure: 1 or 2"),
        ArgSpec::opt("out-dir", "out", "output directory"),
        ArgSpec::opt("seed", "42", "dataset seed"),
    ];
    if args.iter().any(|a| a == "--help") {
        println!("{}", render_help("figures", "regenerate Fig. 1 / Fig. 2", &spec));
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let fig_no = p.get_usize("fig")?;
    let seed = p.get_usize("seed")? as u64;
    // paper captions: Fig1 m=1000 nu1=.5 nu2=.01 eps=2/3;
    //                 Fig2 m=2000 nu1=.2 nu2=.08 eps=1/2
    let (m, nu1, nu2, eps) = match fig_no {
        1 => (1000, 0.5, 0.01, 2.0 / 3.0),
        2 => (2000, 0.2, 0.08, 0.5),
        other => {
            return Err(Error::config(format!("no figure {other} in the paper")))
        }
    };
    let ds = SlabConfig::default().generate(m, seed);
    let report = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .nu1(nu1)
        .nu2(nu2)
        .eps(eps)
        .fit(&ds.x)?;
    let model = report.model;
    println!(
        "fig {fig_no}: m={m} iterations={} rho1={:.4} rho2={:.4} width={:.4}",
        report.stats.iterations,
        model.rho1,
        model.rho2,
        model.width()
    );
    let title = format!(
        "Fig. {fig_no}: OCSSVM slab, m={m}, nu1={nu1}, nu2={nu2}, eps={eps:.3}"
    );
    let fig = slabsvm::figures::build_figure(&model, &ds, &title);
    let dir = std::path::PathBuf::from(p.get_str("out-dir")?);
    std::fs::create_dir_all(&dir)?;
    let csv = dir.join(format!("fig{fig_no}.csv"));
    let svg = dir.join(format!("fig{fig_no}.svg"));
    slabsvm::figures::write_csv(&fig, &csv)?;
    slabsvm::figures::write_svg(&fig, &svg)?;
    println!("wrote {} and {}", csv.display(), svg.display());
    Ok(())
}

// ------------------------------------------------------------------- bench

fn cmd_bench(args: &[String]) -> Result<()> {
    let spec = vec![
        ArgSpec::opt("which", "table1", "table1 | qp | heuristics"),
        ArgSpec::opt("seeds", "3", "seeds per configuration"),
    ];
    if args.iter().any(|a| a == "--help") {
        println!("{}", render_help("bench", "print paper tables", &spec));
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let seeds = p.get_usize("seeds")?;
    match p.get_str("which")? {
        "table1" => bench_table1(seeds),
        "qp" => bench_qp(seeds),
        "heuristics" => bench_heuristics(seeds),
        other => Err(Error::config(format!("unknown bench {other}"))),
    }
}

/// Table 1: training time + MCC vs m (linear kernel, paper constants).
fn bench_table1(seeds: usize) -> Result<()> {
    // nu1=.5 nu2=.01 eps=2/3 as in the paper (the Trainer defaults)
    let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
    println!("Table 1 — SMO training time and MCC vs m (linear kernel)");
    println!(
        "{:>6} {:>12} {:>10} {:>8} {:>12}",
        "m", "time(s)", "MCC", "SVs", "iterations"
    );
    for &m in &[500usize, 1000, 2000, 5000] {
        let mut times = Vec::new();
        let mut mccs = Vec::new();
        let mut svs = 0;
        let mut iters = 0;
        for seed in 0..seeds as u64 {
            let ds = SlabConfig::default().generate(m, 1000 + seed);
            let report = trainer.fit(&ds.x)?;
            let eval =
                SlabConfig::default().generate_eval(m / 2, m / 2, 2000 + seed);
            let c = report.model.evaluate(&eval);
            times.push(report.stats.seconds);
            mccs.push(c.mcc());
            svs = report.model.n_sv();
            iters = report.stats.iterations;
        }
        println!(
            "{m:>6} {:>12.3} {:>10.3} {svs:>8} {iters:>12}",
            slabsvm::linalg::median(&times),
            slabsvm::linalg::mean(&mccs),
        );
    }
    println!(
        "paper reports: 500->0.35s/0.07  1000->0.67s/0.13  2000->2.1s/0.26  5000->5.91s/0.33"
    );
    Ok(())
}

/// SMO vs generic QP solvers (the paper's scaling claim). One Trainer
/// per [`SolverKind`] — the dispatch the unified API exists for.
fn bench_qp(seeds: usize) -> Result<()> {
    println!("SMO vs generic QP solvers — median training seconds");
    println!("{:>6} {:>12} {:>12} {:>12}", "m", "smo", "pg", "ipm");
    for &m in &[250usize, 500, 1000, 2000] {
        let mut medians = Vec::new();
        for kind in [SolverKind::Smo, SolverKind::Pg, SolverKind::Ipm] {
            if kind == SolverKind::Ipm && m > 1000 {
                medians.push("   (skipped)".to_string());
                continue;
            }
            let trainer = Trainer::new(kind).kernel(Kernel::Linear);
            let mut times = Vec::new();
            for seed in 0..seeds as u64 {
                let ds = SlabConfig::default().generate(m, 3000 + seed);
                times.push(trainer.fit(&ds.x)?.stats.seconds);
            }
            medians.push(format!("{:>12.3}", slabsvm::linalg::median(&times)));
        }
        println!("{m:>6} {} {} {}", medians[0], medians[1], medians[2]);
    }
    Ok(())
}

/// Working-set heuristic ablation (A1).
fn bench_heuristics(seeds: usize) -> Result<()> {
    use slabsvm::solver::Heuristic;
    println!("Working-set heuristics — median iterations / seconds (m=2000)");
    println!("{:>18} {:>12} {:>12}", "heuristic", "iterations", "time(s)");
    for h in Heuristic::ALL {
        let trainer = Trainer::new(SolverKind::Smo)
            .kernel(Kernel::Linear)
            .heuristic(h);
        let mut iters = Vec::new();
        let mut times = Vec::new();
        for seed in 0..seeds as u64 {
            let ds = SlabConfig::default().generate(2000, 4000 + seed);
            let report = trainer.fit(&ds.x)?;
            iters.push(report.stats.iterations as f64);
            times.push(report.stats.seconds);
        }
        println!(
            "{:>18} {:>12.0} {:>12.3}",
            h.name(),
            slabsvm::linalg::median(&iters),
            slabsvm::linalg::median(&times)
        );
    }
    Ok(())
}

// ------------------------------------------------------------------- serve

fn cmd_serve(args: &[String]) -> Result<()> {
    use slabsvm::serve::{
        Auth, RateConfig, Router, RouterConfig, ServerConfig,
    };
    use slabsvm::stream::{StreamConfig, StreamPoolConfig, StreamSpec};
    use std::sync::Arc;

    let spec = vec![
        ArgSpec::opt("addr", "127.0.0.1:8080", "bind address (port 0 = pick a free port)"),
        ArgSpec::opt("engine", "native", "compute engine: native|pjrt"),
        ArgSpec::opt("artifacts", "artifacts", "artifacts dir for pjrt"),
        ArgSpec::opt(
            "tenants",
            "demo",
            "comma-separated tenant streams to open (demo model each)",
        ),
        ArgSpec::opt(
            "auth",
            "",
            "bearer tokens: tenant=token,... (empty = open mode)",
        ),
        ArgSpec::opt("rate", "0", "per-tenant admission rate, req/s (0 = unlimited)"),
        ArgSpec::opt("burst", "32", "token-bucket burst for --rate"),
        ArgSpec::opt("max-conns", "1024", "connection cap (503 above it)"),
        ArgSpec::opt("shards", "2", "stream shard worker threads"),
        ArgSpec::opt("mailbox", "1024", "per-stream queue bound (429 when full)"),
        ArgSpec::opt("window", "256", "sliding-window capacity"),
        ArgSpec::opt("min-train", "64", "samples before the first publish"),
        ArgSpec::opt("batch", "256", "batcher max batch"),
        ArgSpec::opt("wait-us", "500", "batcher max wait (us)"),
        ArgSpec::opt("workers", "2", "scoring worker threads"),
        ArgSpec::opt(
            "score-queue-cap",
            "8192",
            "batcher queue bound (stale-model fallback above it)",
        ),
        ArgSpec::opt(
            "train-size",
            "256",
            "demo-model training points per tenant (0 = no demo models)",
        ),
        ArgSpec::opt(
            "checkpoint-dir",
            "",
            "checkpoint live sessions here (also the /v1/snapshot target)",
        ),
        ArgSpec::opt("checkpoint-ms", "500", "checkpoint cadence (ms)"),
        ArgSpec::opt(
            "restore-dir",
            "",
            "resume sessions from this snapshot dir at startup",
        ),
        ArgSpec::opt("duration-s", "0", "serve this long then exit (0 = forever)"),
    ];
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help(
                "serve",
                "HTTP/1.1 front door for scoring + tenant streams (DESIGN.md §9)",
                &spec
            )
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let engine = make_engine(&p)?;
    let cfg = BatcherConfig {
        max_batch: p.get_usize("batch")?,
        max_wait_us: p.get_usize("wait-us")? as u64,
        queue_cap: p.get_usize("score-queue-cap")?,
    };
    let ckpt_dir = p.get_str("checkpoint-dir")?.to_string();
    let checkpoint = if ckpt_dir.is_empty() {
        None
    } else {
        std::fs::create_dir_all(&ckpt_dir)?;
        Some(slabsvm::stream::CheckpointConfig::new(
            ckpt_dir.as_str(),
            std::time::Duration::from_millis(
                p.get_usize("checkpoint-ms")? as u64
            ),
        ))
    };
    println!("starting coordinator (engine={}, {:?})", engine.name(), cfg);
    let c = Arc::new(Coordinator::start_with_streams(
        engine,
        cfg,
        p.get_usize("workers")?,
        StreamPoolConfig {
            shards: p.get_usize("shards")?,
            mailbox_cap: p.get_usize("mailbox")?,
            checkpoint,
        },
    ));

    // resume a snapshotted fleet before opening anything new
    let mut restored = Vec::new();
    let restore_dir = p.get_str("restore-dir")?;
    if !restore_dir.is_empty() {
        for o in c.restore_streams(std::path::Path::new(restore_dir))? {
            match o.result {
                Ok(r) => {
                    println!(
                        "restored '{}': {} updates, v{}, repaired={}",
                        r.name,
                        r.updates,
                        r.version.unwrap_or(0),
                        r.repaired
                    );
                    restored.push(r);
                }
                Err(e) => {
                    eprintln!("restore {} failed: {e}", o.file.display())
                }
            }
        }
    }

    // one managed stream per tenant (restored ones are already open),
    // plus an immediately scoreable demo model under the same name
    let tenants: Vec<String> = p
        .get_str("tenants")?
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(str::to_string)
        .collect();
    let stream_cfg = StreamConfig {
        kernel: Kernel::Linear,
        dim: 2,
        window: p.get_usize("window")?,
        min_train: p.get_usize("min-train")?,
        ..Default::default()
    };
    let to_open: Vec<StreamSpec> = tenants
        .iter()
        .filter(|t| !c.stream_manager().is_open(t))
        .map(|t| StreamSpec::new(t.clone(), stream_cfg.clone()))
        .collect();
    if !to_open.is_empty() {
        c.open_streams(to_open)?;
    }
    let train_size = p.get_usize("train-size")?;
    if train_size > 0 {
        for (i, t) in tenants.iter().enumerate() {
            if c.model(t).is_none() {
                let ds =
                    SlabConfig::default().generate(train_size, 42 + i as u64);
                c.train_blocking(
                    t,
                    &ds,
                    &Trainer::new(SolverKind::Smo).kernel(Kernel::Linear),
                )?;
            }
        }
    }
    println!(
        "tenants: {} (streams open: {})",
        tenants.join(","),
        c.stream_manager().open_count()
    );

    let auth = Auth::from_spec(p.get_str("auth")?)?;
    if !auth.is_open() {
        println!("auth: bearer tokens for {}", auth.tenants().join(","));
    }
    let rate = p.get_f64("rate")?;
    let router = Arc::new(Router::new(
        Arc::clone(&c),
        RouterConfig {
            auth,
            rate: (rate > 0.0).then_some(RateConfig {
                per_second: rate,
                burst: p.get_f64("burst")?,
            }),
            snapshot_dir: (!ckpt_dir.is_empty())
                .then(|| std::path::PathBuf::from(&ckpt_dir)),
        },
    ));
    router.note_restored(&restored);

    let mut server = slabsvm::serve::start(
        Arc::clone(&router),
        ServerConfig {
            addr: p.get_str("addr")?.to_string(),
            max_conns: p.get_usize("max-conns")?,
            ..ServerConfig::default()
        },
    )?;
    // the E2E tests and the serve-smoke CI lane parse this line to
    // discover the bound port — keep its shape stable
    println!("listening on {}", server.addr());

    let duration = p.get_usize("duration-s")?;
    if duration == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration as u64));
    server.shutdown();
    c.quiesce_streams();
    if !ckpt_dir.is_empty() {
        for o in c.snapshot_streams(std::path::Path::new(&ckpt_dir))? {
            if let Err(e) = o.result {
                eprintln!("final snapshot of '{}' failed: {e}", o.name);
            }
        }
    }
    println!("stats: {}", c.stats().summary());
    println!("stream stats: {}", c.stats().stream_summary());
    Ok(())
}

// ------------------------------------------------------------------ stream

fn cmd_stream(args: &[String]) -> Result<()> {
    use slabsvm::data::synthetic::{Drift, DriftSchedule, SlabStream};
    use slabsvm::stream::StreamConfig;

    let mut spec = vec![
        ArgSpec::opt("points", "3000", "stream length (samples, per stream)"),
        ArgSpec::opt("streams", "1", "concurrent tenant streams (>1 = sharded manager)"),
        ArgSpec::opt("shards", "2", "shard worker threads for --streams > 1"),
        ArgSpec::opt("mailbox", "2048", "per-stream queue bound (samples)"),
        ArgSpec::opt("window", "512", "sliding-window capacity"),
        ArgSpec::opt("min-train", "128", "samples before the first publish"),
        ArgSpec::opt("nu1", "0.5", "nu1 (lower-plane outlier bound)"),
        ArgSpec::opt("nu2", "0.01", "nu2 (upper-plane violator bound)"),
        ArgSpec::opt("eps", "0.6666666666666666", "eps (upper-plane mass)"),
        ArgSpec::opt(
            "drift",
            "mean-shift",
            "injected drift: none|mean-shift|variance|rotation",
        ),
        ArgSpec::opt("drift-at", "1500", "sample index the drift ramp starts"),
        ArgSpec::opt("drift-len", "200", "ramp length in samples (0 = step)"),
        ArgSpec::opt(
            "drift-amount",
            "-8.0",
            "drift magnitude (offset delta | spread factor | radians)",
        ),
        ArgSpec::opt("seed", "42", "stream seed"),
        ArgSpec::opt("report-every", "500", "progress line cadence"),
        ArgSpec::opt(
            "restore-dir",
            "",
            "resume sessions from this snapshot directory before streaming",
        ),
        ArgSpec::opt(
            "snapshot-dir",
            "",
            "write a final snapshot of every stream here when done",
        ),
        ArgSpec::opt(
            "checkpoint-dir",
            "",
            "periodically checkpoint live sessions into this directory",
        ),
        ArgSpec::opt(
            "checkpoint-ms",
            "1000",
            "per-stream checkpoint cadence for --checkpoint-dir (ms)",
        ),
        ArgSpec::opt(
            "evict",
            "fifo",
            "window-eviction policy: fifo|interior-first",
        ),
        ArgSpec::opt(
            "engine",
            "exact",
            "streaming engine: exact|nystroem|rff (lifted approx absorbs)",
        ),
        ArgSpec::opt(
            "features",
            "64",
            "lifted feature budget for --engine nystroem|rff",
        ),
    ];
    spec.extend(kernel_args());
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help(
                "stream",
                "incremental online learning on a drifting synthetic stream",
                &spec
            )
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let kernel = parse_kernel_from(&p)?;
    let points = p.get_usize("points")?;
    let report_every = p.get_usize("report-every")?.max(1);

    let mut cfg = StreamConfig {
        kernel,
        dim: 2,
        window: p.get_usize("window")?,
        min_train: p.get_usize("min-train")?,
        ..Default::default()
    };
    cfg.incremental.smo.nu1 = p.get_f64("nu1")?;
    cfg.incremental.smo.nu2 = p.get_f64("nu2")?;
    cfg.incremental.smo.eps = p.get_f64("eps")?;
    cfg.incremental.policy = p.get_str("evict")?.parse()?;
    cfg.incremental.engine = p.get_str("engine")?.parse()?;
    cfg.incremental.features = p.get_usize("features")?;

    let amount = p.get_f64("drift-amount")?;
    let drift = match p.get_str("drift")? {
        "none" => None,
        "mean-shift" => Some(Drift::MeanShift { delta: amount }),
        "variance" => Some(Drift::VarianceInflation { factor: amount.abs() }),
        "rotation" => Some(Drift::Rotation { delta: amount }),
        other => {
            return Err(Error::config(format!(
                "unknown drift {other:?} (expected none|mean-shift|variance|rotation)"
            )))
        }
    };
    let n_streams = p.get_usize("streams")?.max(1);
    if n_streams > 1 {
        return run_multi_stream(&p, cfg, drift, points, n_streams);
    }

    let mut stream = SlabStream::new(
        SlabConfig::default(),
        p.get_usize("seed")? as u64,
    );
    if let Some(d) = drift {
        stream = stream.with_drift(DriftSchedule {
            drift: d,
            start: p.get_usize("drift-at")?,
            duration: p.get_usize("drift-len")?,
        });
        println!(
            "drift: {d:?} ramping from sample {} over {}",
            p.get_usize("drift-at")?,
            p.get_usize("drift-len")?
        );
    }

    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 2);
    let restore_dir = p.get_str("restore-dir")?;
    let mut session = if restore_dir.is_empty() {
        c.open_stream("stream", cfg)
    } else {
        let path = slabsvm::stream::persist::snapshot_path(
            std::path::Path::new(restore_dir),
            "stream",
        );
        let snap = slabsvm::stream::persist::read_snapshot(&path)?;
        use slabsvm::stream::Snapshot;
        if Snapshot::config_fingerprint(&snap.cfg)
            != Snapshot::config_fingerprint(&cfg)
        {
            println!(
                "note: snapshot config differs from the CLI flags; the \
                 snapshotted configuration governs the restored session"
            );
        }
        let (session, info) = snap.into_session()?;
        println!(
            "restored '{}' from {}: {} updates, window {}/{}, \
             repaired={}",
            session.name(),
            path.display(),
            session.updates(),
            session.solver().len(),
            session.config().window,
            info.repaired
        );
        session
    };
    println!(
        "streaming {points} samples through window={} min_train={} kernel={}",
        session.config().window,
        session.config().min_train,
        kernel.family()
    );

    let t0 = std::time::Instant::now();
    let mut last_version = 0u64;
    let mut drift_samples = 0u64;
    let mut retrains_done = 0u64;
    for i in 0..points {
        let x = stream.next_point();
        let u = c.stream_push(&mut session, &x)?;
        if let Some(v) = u.version {
            last_version = v;
        }
        if u.drift.is_some() {
            drift_samples += 1;
        }
        if let Some(id) = u.retrain_submitted {
            println!(
                "[{i}] drift {:?} → background cascade retrain {id:?}",
                u.drift
            );
        }
        if let Some(v) = u.retrain_completed {
            retrains_done += 1;
            println!("[{i}] background retrain landed → model v{v}");
        }
        if (i + 1) % report_every == 0 {
            let (r1, r2) = session.solver().rho();
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "[{}] v{last_version} rho=[{r1:.3}, {r2:.3}] outside={:.2} \
                 {:.0} updates/s",
                i + 1,
                session.drift_monitor().outside_fraction(),
                (i + 1) as f64 / dt
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done: {points} updates in {dt:.2}s ({:.0} updates/s), final model \
         v{last_version}, {} drift-flagged samples, {retrains_done} background \
         retrains, {} total repair iterations",
        points as f64 / dt,
        drift_samples,
        session.solver().repair_iterations()
    );
    let snap_dir = p.get_str("snapshot-dir")?;
    if !snap_dir.is_empty() {
        let dir = std::path::Path::new(snap_dir);
        std::fs::create_dir_all(dir)?;
        let path =
            slabsvm::stream::persist::snapshot_path(dir, session.name());
        slabsvm::stream::persist::write_atomic(&path, &session.snapshot())?;
        println!("snapshot written to {}", path.display());
    }
    c.shutdown();
    Ok(())
}

/// `slabsvm stream --streams M`: M tenant streams driven concurrently
/// through the sharded session manager — M producer threads enqueue
/// onto shard mailboxes, shard workers absorb fairly and hot-swap each
/// tenant's published model.
fn run_multi_stream(
    p: &Parsed,
    cfg: slabsvm::stream::StreamConfig,
    drift: Option<slabsvm::data::synthetic::Drift>,
    points: usize,
    n_streams: usize,
) -> Result<()> {
    use slabsvm::data::synthetic::{DriftSchedule, SlabStream};
    use slabsvm::stream::{StreamPoolConfig, StreamSpec};

    let shards = p.get_usize("shards")?.max(1);
    let seed0 = p.get_usize("seed")? as u64;
    let drift_at = p.get_usize("drift-at")?;
    let drift_len = p.get_usize("drift-len")?;

    let ckpt_dir = p.get_str("checkpoint-dir")?;
    let checkpoint = if ckpt_dir.is_empty() {
        None
    } else {
        std::fs::create_dir_all(ckpt_dir)?;
        println!(
            "checkpointing every {}ms into {ckpt_dir}",
            p.get_usize("checkpoint-ms")?
        );
        Some(slabsvm::stream::CheckpointConfig::new(
            ckpt_dir,
            std::time::Duration::from_millis(
                p.get_usize("checkpoint-ms")? as u64
            ),
        ))
    };
    let c = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        2,
        StreamPoolConfig {
            shards,
            mailbox_cap: p.get_usize("mailbox")?,
            checkpoint,
        },
    );

    // resume everything a previous run snapshotted, then cold-open the
    // rest of the fleet — a restarted coordinator picks up where the
    // old one stopped, no cold window refills
    let restore_dir = p.get_str("restore-dir")?;
    if !restore_dir.is_empty() {
        let mut any_restored = false;
        for o in c.restore_streams(std::path::Path::new(restore_dir))? {
            match o.result {
                Ok(r) => {
                    any_restored = true;
                    println!(
                        "restored '{}': {} updates, v{}, repaired={}",
                        r.name,
                        r.updates,
                        r.version.unwrap_or(0),
                        r.repaired
                    );
                }
                Err(e) => {
                    eprintln!("restore {} failed: {e}", o.file.display())
                }
            }
        }
        if any_restored {
            println!(
                "note: restored tenants keep their snapshotted \
                 configuration; stream flags apply only to newly \
                 opened tenants"
            );
        }
    }
    let missing: Vec<StreamSpec> = (0..n_streams)
        .map(|i| format!("tenant-{i}"))
        .filter(|name| !c.stream_manager().is_open(name))
        .map(|name| StreamSpec::new(name, cfg))
        .collect();
    if !missing.is_empty() {
        c.open_streams(missing)?;
    }
    println!(
        "streaming {points} samples x {n_streams} tenants through {shards} \
         shard workers (window={}, min_train={})",
        cfg.window, cfg.min_train
    );
    if let Some(d) = drift {
        println!(
            "drift: {d:?} ramping from sample {drift_at} over {drift_len} \
             (every tenant, independent seeds)"
        );
    }

    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for i in 0..n_streams {
            let c = &c;
            scope.spawn(move || {
                let mut stream =
                    SlabStream::new(SlabConfig::default(), seed0 + i as u64);
                if let Some(d) = drift {
                    stream = stream.with_drift(DriftSchedule {
                        drift: d,
                        start: drift_at,
                        duration: drift_len,
                    });
                }
                let name = format!("tenant-{i}");
                for _ in 0..points {
                    let x = stream.next_point();
                    if c.push(&name, &x).is_err() {
                        break;
                    }
                }
            });
        }
    });
    c.quiesce_streams();
    let dt = t0.elapsed().as_secs_f64();

    let snap_dir = p.get_str("snapshot-dir")?;
    if !snap_dir.is_empty() {
        let outcomes =
            c.snapshot_streams(std::path::Path::new(snap_dir))?;
        let ok = outcomes.iter().filter(|o| o.result.is_ok()).count();
        println!("snapshotted {ok}/{} streams into {snap_dir}", outcomes.len());
        for o in &outcomes {
            if let Err(e) = &o.result {
                eprintln!("snapshot '{}' failed: {e}", o.name);
            }
        }
    }

    let mut total_retrains = 0u64;
    for i in 0..n_streams {
        let s = c.close_stream(&format!("tenant-{i}"))?;
        total_retrains += s.retrains;
        println!(
            "  {}: {} updates, {} retrains, v{}, slab=[{:.3}, {:.3}]",
            s.name,
            s.updates,
            s.retrains,
            s.version.unwrap_or(0),
            s.rho.0,
            s.rho.1
        );
    }
    let total = (points * n_streams) as f64;
    println!(
        "aggregate: {} samples over {n_streams} tenants in {dt:.2}s \
         ({:.0} updates/s) on {shards} shards, {total_retrains} background \
         retrains",
        total as u64,
        total / dt
    );
    println!("streams: {}", c.stats().stream_summary());
    c.shutdown();
    Ok(())
}

// --------------------------------------------------------- stats / trace

/// Shared flags of the observability verbs' driven workload.
fn obs_args() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("points", "300", "samples per stream in the driven workload"),
        ArgSpec::opt("streams", "2", "tenant streams"),
        ArgSpec::opt("shards", "2", "shard worker threads"),
        ArgSpec::opt("window", "128", "sliding-window capacity"),
        ArgSpec::opt("min-train", "64", "samples before the first publish"),
        ArgSpec::opt("seed", "42", "stream seed"),
    ]
}

/// Drive a short synthetic multi-tenant run with the recorder enabled
/// and return the still-live coordinator — `slabsvm stats` and `slabsvm
/// trace` share this workload so their exports describe the same kind
/// of run (and CI smoke-validates both against it, DESIGN.md §8).
fn obs_workload(p: &Parsed) -> Result<Coordinator> {
    use slabsvm::data::synthetic::SlabStream;
    use slabsvm::stream::{StreamConfig, StreamPoolConfig, StreamSpec};

    slabsvm::obs::set_enabled(true);
    let n_streams = p.get_usize("streams")?.max(1);
    let points = p.get_usize("points")?;
    let seed0 = p.get_usize("seed")? as u64;
    let cfg = StreamConfig {
        dim: 2,
        window: p.get_usize("window")?,
        min_train: p.get_usize("min-train")?,
        ..Default::default()
    };
    let c = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        2,
        StreamPoolConfig {
            shards: p.get_usize("shards")?.max(1),
            mailbox_cap: 2048,
            checkpoint: None,
        },
    );
    c.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("tenant-{i}"), cfg))
            .collect(),
    )?;
    for i in 0..n_streams {
        let mut stream =
            SlabStream::new(SlabConfig::default(), seed0 + i as u64);
        let name = format!("tenant-{i}");
        for _ in 0..points {
            let x = stream.next_point();
            c.push(&name, &x)?;
        }
    }
    c.quiesce_streams();
    Ok(c)
}

/// `slabsvm stats`: every service metric after a short traced run, in
/// Prometheus text exposition (default) or JSON lines.
fn cmd_stats(args: &[String]) -> Result<()> {
    let mut spec = obs_args();
    spec.push(ArgSpec::opt("format", "prom", "export format: prom|json"));
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help(
                "stats",
                "drive a traced synthetic workload, print the metrics export",
                &spec
            )
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let format = p.get_str("format")?.to_string();
    if format != "prom" && format != "json" {
        return Err(Error::config(format!(
            "unknown format {format:?} (expected prom|json)"
        )));
    }
    let c = obs_workload(&p)?;
    if format == "json" {
        print!("{}", c.metrics_json());
    } else {
        print!("{}", c.metrics_text());
    }
    c.shutdown();
    Ok(())
}

/// `slabsvm trace`: the most recent spans after a short traced run —
/// each line one JSON span with trace id, stage, interval, stream/shard
/// and solver iterations — plus, with --events, the drained
/// flight-recorder events.
fn cmd_trace(args: &[String]) -> Result<()> {
    let mut spec = obs_args();
    spec.push(ArgSpec::opt("limit", "64", "most recent spans to print"));
    spec.push(ArgSpec::flag(
        "events",
        "also print the drained flight-recorder events",
    ));
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help(
                "trace",
                "drive a traced synthetic workload, print span chains as JSONL",
                &spec
            )
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let limit = p.get_usize("limit")?.max(1);
    let c = obs_workload(&p)?;
    for span in slabsvm::obs::recent_spans(limit) {
        println!("{}", span.to_json());
    }
    if p.flag("events") {
        for e in slabsvm::obs::drain_events() {
            println!("{}", e.to_json());
        }
    }
    c.shutdown();
    Ok(())
}

// ---------------------------------------------------------------- snapshot

/// `slabsvm snapshot`: either describe one snapshot file (--inspect —
/// the format is self-describing, everything prints from the file
/// alone) or drive a short synthetic multi-tenant fleet and write a
/// restorable snapshot directory (the input for `slabsvm stream
/// --restore-dir`).
fn cmd_snapshot(args: &[String]) -> Result<()> {
    use slabsvm::data::synthetic::SlabStream;
    use slabsvm::stream::{persist, StreamConfig, StreamPoolConfig, StreamSpec};

    let spec = vec![
        ArgSpec::opt("inspect", "", "describe this snapshot file and exit"),
        ArgSpec::opt("out", "snapshots", "snapshot directory to write"),
        ArgSpec::opt("points", "600", "samples per stream before snapshotting"),
        ArgSpec::opt("streams", "2", "tenant streams"),
        ArgSpec::opt("shards", "2", "shard worker threads"),
        ArgSpec::opt("window", "128", "sliding-window capacity"),
        ArgSpec::opt("min-train", "64", "samples before the first publish"),
        ArgSpec::opt("seed", "42", "stream seed"),
        ArgSpec::opt(
            "evict",
            "fifo",
            "window-eviction policy: fifo|interior-first",
        ),
    ];
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help(
                "snapshot",
                "write durable stream snapshots, or --inspect one",
                &spec
            )
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;

    let inspect = p.get_str("inspect")?;
    if !inspect.is_empty() {
        let snap = persist::read_snapshot(std::path::Path::new(inspect))?;
        println!("{}", snap.describe());
        return Ok(());
    }

    let n_streams = p.get_usize("streams")?.max(1);
    let points = p.get_usize("points")?;
    let seed0 = p.get_usize("seed")? as u64;
    let mut cfg = StreamConfig {
        dim: 2,
        window: p.get_usize("window")?,
        min_train: p.get_usize("min-train")?,
        ..Default::default()
    };
    cfg.incremental.policy = p.get_str("evict")?.parse()?;
    cfg.incremental.engine = p.get_str("engine")?.parse()?;
    cfg.incremental.features = p.get_usize("features")?;
    let c = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        2,
        StreamPoolConfig {
            shards: p.get_usize("shards")?.max(1),
            mailbox_cap: 2048,
            checkpoint: None,
        },
    );
    c.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("tenant-{i}"), cfg))
            .collect(),
    )?;
    println!("feeding {points} samples x {n_streams} tenants before snapshot");
    for i in 0..n_streams {
        let mut stream =
            SlabStream::new(SlabConfig::default(), seed0 + i as u64);
        let name = format!("tenant-{i}");
        for _ in 0..points {
            c.push(&name, &stream.next_point())?;
        }
    }
    c.quiesce_streams();
    let dir = std::path::PathBuf::from(p.get_str("out")?);
    let outcomes = c.snapshot_streams(&dir)?;
    for o in &outcomes {
        match &o.result {
            Ok(()) => println!(
                "  {} -> {}",
                o.name,
                persist::snapshot_path(&dir, &o.name).display()
            ),
            Err(e) => eprintln!("  {} FAILED: {e}", o.name),
        }
    }
    let ok = outcomes.iter().filter(|o| o.result.is_ok()).count();
    println!(
        "snapshotted {ok}/{} streams into {} (restore with: slabsvm stream \
         --streams {n_streams} --restore-dir {})",
        outcomes.len(),
        dir.display(),
        dir.display()
    );
    c.shutdown();
    Ok(())
}

// ------------------------------------------------------------------ forget

/// `slabsvm forget`: offline targeted unlearning — load a stream
/// snapshot, remove the given sample ids (withdrawing their dual mass
/// and repairing with the warm-started bounded sweep), and write the
/// shrunk session back as a fresh snapshot. The result restores like
/// any other snapshot (`slabsvm stream --restore-dir`), so "forget
/// user X" works on a fleet at rest without replaying the stream.
fn cmd_forget(args: &[String]) -> Result<()> {
    use slabsvm::stream::{persist, Snapshot};

    let spec = vec![
        ArgSpec::req("snapshot", "path to the stream snapshot to edit"),
        ArgSpec::req(
            "id",
            "comma-separated stable sample ids (0-based arrival indices)",
        ),
        ArgSpec::opt("out", "", "output path (default: rewrite in place)"),
    ];
    if args.iter().any(|a| a == "--help") {
        println!(
            "{}",
            render_help(
                "forget",
                "remove samples by id from a snapshot, repair, write back",
                &spec
            )
        );
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let path = std::path::PathBuf::from(p.get_str("snapshot")?);
    let ids: Vec<u64> = p
        .get_str("id")?
        .split(',')
        .map(|t| {
            t.trim().parse::<u64>().map_err(|_| {
                Error::config(format!("--id: not a sample id: {t:?}"))
            })
        })
        .collect::<Result<_>>()?;

    let snap = persist::read_snapshot(&path)?;
    let before = snap.len;
    // the manager-layer envelope must survive the edit: dropping the
    // fair-share weight or the registry version watermark would make a
    // later --restore-dir regress published versions / scheduling
    let (weight, last_version) = (snap.weight, snap.last_version);
    let (mut session, info) = snap.into_session()?;
    if info.repaired {
        println!("note: snapshot state needed a repair sweep on load");
    }
    // one batch withdrawal: a single repair sweep for the whole id list
    // instead of k sequential forget/repair rounds
    session.forget_many(&ids)?;
    for &id in &ids {
        println!("forgot sample {id} from '{}'", session.name());
    }
    let (r1, r2) = session.solver().rho();
    println!(
        "window {} -> {} resident, rho=[{r1:.6}, {r2:.6}], {} forgets \
         over the stream's lifetime",
        before,
        session.solver().len(),
        session.forgets()
    );
    let out_str = p.get_str("out")?;
    let out = if out_str.is_empty() {
        path
    } else {
        std::path::PathBuf::from(out_str)
    };
    let bytes =
        Snapshot::capture(&session, weight, Some(last_version)).encode();
    persist::write_atomic(&out, &bytes)?;
    println!(
        "snapshot written to {} (format v{})",
        out.display(),
        persist::FORMAT_VERSION
    );
    let _ = Snapshot::decode(&std::fs::read(&out)?)?; // self-check
    Ok(())
}

// ------------------------------------------------------------------- sweep

fn cmd_sweep(args: &[String]) -> Result<()> {
    let mut spec = vec![
        ArgSpec::opt("folds", "3", "cross-validation folds"),
        ArgSpec::opt("nu1", "0.05,0.1,0.2,0.5", "comma-separated nu1 grid"),
        ArgSpec::opt("nu2", "0.01,0.05,0.1", "comma-separated nu2 grid"),
        ArgSpec::opt("eps-grid", "0.3,0.5,0.667", "comma-separated eps grid"),
        ArgSpec::opt("top", "10", "rows to print"),
        ArgSpec::flag("json", "emit one JSON line per grid point"),
    ];
    spec.extend(data_args());
    spec.extend(kernel_args());
    if args.iter().any(|a| a == "--help") {
        println!("{}", render_help("sweep", "CV grid search", &spec));
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let train = load_dataset(&p)?.positives_only();
    // negatives for the CV metric: synthetic off-band anomalies
    let negatives = SlabConfig::default()
        .generate_eval(0, (train.len() / 2).max(50), p.get_usize("seed")? as u64 ^ 0xabc)
        .select(&(0..(train.len() / 2).max(50)).collect::<Vec<_>>());
    let kernel = parse_kernel_from(&p)?;

    let parse_grid = |key: &str| -> Result<Vec<f64>> {
        p.get_str(key)?
            .split(',')
            .map(|t| {
                t.trim().parse::<f64>().map_err(|_| {
                    Error::config(format!("--{key}: bad number {t:?}"))
                })
            })
            .collect()
    };
    let nu1s = parse_grid("nu1")?;
    let nu2s = parse_grid("nu2")?;
    let epss = parse_grid("eps-grid")?;
    let folds = p.get_usize("folds")?;
    println!(
        "sweeping {} grid points, {folds}-fold CV, {} training points",
        nu1s.len() * nu2s.len() * epss.len(),
        train.len()
    );
    let results = slabsvm::data::cv::grid_search(
        &train, &negatives, &[kernel], &nu1s, &nu2s, &epss, folds,
        p.get_usize("seed")? as u64,
    )?;
    println!(
        "{:>6} {:>6} {:>6} | {:>8} {:>12}",
        "nu1", "nu2", "eps", "mean MCC", "train s/fold"
    );
    for r in results.iter().take(p.get_usize("top")?) {
        println!(
            "{:>6} {:>6} {:>6.3} | {:>8.3} {:>12.3}",
            r.params.nu1, r.params.nu2, r.params.eps, r.mean_mcc,
            r.mean_train_seconds
        );
        if p.flag("json") {
            use slabsvm::util::json::Json;
            println!(
                "SWEEPJSON {}",
                Json::obj(vec![
                    ("nu1", Json::num(r.params.nu1)),
                    ("nu2", Json::num(r.params.nu2)),
                    ("eps", Json::num(r.params.eps)),
                    ("mean_mcc", Json::num(r.mean_mcc)),
                    (
                        "fold_mcc",
                        Json::arr(
                            r.fold_mcc.iter().map(|&v| Json::num(v)).collect()
                        )
                    ),
                ])
            );
        }
    }
    Ok(())
}

// -------------------------------------------------------------------- info

fn cmd_info(args: &[String]) -> Result<()> {
    let spec = vec![ArgSpec::opt("artifacts", "artifacts", "artifacts directory")];
    if args.iter().any(|a| a == "--help") {
        println!("{}", render_help("info", "manifest + engine diagnostics", &spec));
        return Ok(());
    }
    let p = parse_args(&spec, args)?;
    let dir = p.get_str("artifacts")?;
    match slabsvm::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!(
                "manifest: {} artifacts | m buckets {:?} | d buckets {:?} | q buckets {:?}",
                m.artifacts.len(),
                m.m_buckets,
                m.d_buckets,
                m.q_buckets
            );
            for a in &m.artifacts {
                println!(
                    "  {:10} family={:8} m={:5} d={:2} q={:3}  {}",
                    format!("{:?}", a.kind).to_lowercase(),
                    a.family,
                    a.m,
                    a.d,
                    a.q,
                    a.path.file_name().unwrap_or_default().to_string_lossy()
                );
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    println!(
        "threads available: {}",
        slabsvm::util::threadpool::default_threads()
    );
    Ok(())
}
