//! PJRT executor: compile-once, execute-many wrappers per artifact.
//!
//! One [`PjrtEngine`] owns the CPU PJRT client and a cache of compiled
//! executables keyed by artifact path — an artifact is parsed + compiled
//! at most once per process, then every call is a pure execute (this is
//! the property that makes the serving hot path Python-free and
//! compile-free).
//!
//! Padding contract (see python/compile/model.py): problems are padded
//! up to the artifact's shape bucket with zero rows and γ = 0; padded
//! entries are inert in every contraction, and outputs are sliced back
//! to the logical size.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactInfo, ArtifactKind, Manifest};
use crate::error::{Error, Result};
use crate::kernel::Kernel;
use crate::linalg::Matrix;

/// Per-engine execution counters (exposed via coordinator stats).
#[derive(Clone, Copy, Debug, Default)]
pub struct PjrtStats {
    pub compiles: u64,
    pub executions: u64,
    /// cumulative seconds inside PJRT execute calls
    pub exec_seconds: f64,
}

/// PJRT-backed compute engine over the AOT artifact set.
pub struct PjrtEngine {
    client: PjRtClient,
    manifest: Manifest,
    /// compiled executable cache, keyed by artifact file path
    cache: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    stats: Mutex<PjrtStats>,
}

impl PjrtEngine {
    /// Create from an artifacts directory (must contain manifest.json).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(PjrtEngine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(PjrtStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> PjrtStats {
        *self.stats.lock().unwrap()
    }

    /// Compile (or fetch) the executable for an artifact.
    fn executable(&self, info: &ArtifactInfo) -> Result<()> {
        let key = info.path.to_string_lossy().to_string();
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(&key) {
            return Ok(());
        }
        let proto = HloModuleProto::from_text_file(
            info.path
                .to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
        )?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.stats.lock().unwrap().compiles += 1;
        cache.insert(key, exe);
        Ok(())
    }

    /// Execute an artifact on literals, returning the tuple elements.
    fn run(&self, info: &ArtifactInfo, args: &[Literal]) -> Result<Vec<Literal>> {
        self.executable(info)?;
        let key = info.path.to_string_lossy().to_string();
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(&key).expect("just compiled");
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(args)?[0][0].to_literal_sync()?;
        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.exec_seconds += t0.elapsed().as_secs_f64();
        drop(st);
        Ok(result.to_tuple()?)
    }

    /// Pad an [n, d] matrix into an [m_bucket, d_bucket] f32 literal.
    fn pad_matrix(x: &Matrix, mb: usize, db: usize) -> Result<Literal> {
        let (n, d) = (x.rows(), x.cols());
        let mut flat = vec![0f32; mb * db];
        for i in 0..n {
            for j in 0..d {
                flat[i * db + j] = x.get(i, j) as f32;
            }
        }
        Ok(Literal::vec1(&flat).reshape(&[mb as i64, db as i64])?)
    }

    /// Pad a length-n vector into a length-m f32 literal.
    fn pad_vec(v: &[f64], mb: usize) -> Literal {
        let mut flat = vec![0f32; mb];
        for (i, &x) in v.iter().enumerate() {
            flat[i] = x as f32;
        }
        Literal::vec1(&flat)
    }

    /// Gram matrix via the `kmatrix_*` artifact. Returns None (caller
    /// falls back to native) when n exceeds the largest bucket or the
    /// kernel family was not exported.
    pub fn kmatrix(&self, x: &Matrix, kernel: Kernel) -> Result<Option<Matrix>> {
        let (n, d) = (x.rows(), x.cols());
        let Some(info) = self.manifest.select(
            ArtifactKind::Kmatrix,
            kernel.family(),
            n,
            d,
            0,
        ) else {
            return Ok(None);
        };
        let (mb, db) = (info.m, info.d);
        let xl = Self::pad_matrix(x, mb, db)?;
        let p3 = Literal::vec1(&kernel.params3());
        let out = self.run(info, &[xl, p3])?;
        let kflat = out[0].to_vec::<f32>()?;
        // slice the [mb, mb] result back to [n, n]
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k.set(i, j, kflat[i * mb + j] as f64);
            }
        }
        Ok(Some(k))
    }

    /// Batched decision function via the `decision_*` artifact: scores +
    /// labels for `xq` against a trained model. Queries are chunked to
    /// the largest query bucket. Returns None on bucket overflow.
    pub fn decision(
        &self,
        x_sv: &Matrix,
        gamma: &[f64],
        rho1: f64,
        rho2: f64,
        kernel: Kernel,
        xq: &Matrix,
    ) -> Result<Option<(Vec<f64>, Vec<i8>)>> {
        let (n, d) = (x_sv.rows(), x_sv.cols());
        let nq = xq.rows();
        let Some(qmax) = self.manifest.max_q() else {
            return Ok(None);
        };
        let Some(info) = self.manifest.select(
            ArtifactKind::Decision,
            kernel.family(),
            n,
            d,
            nq.min(qmax),
        ) else {
            return Ok(None);
        };
        let (mb, db, qb) = (info.m, info.d, info.q);

        let xl = Self::pad_matrix(x_sv, mb, db)?;
        let gl = Self::pad_vec(gamma, mb);
        let p = kernel.params3();
        let p5 = Literal::vec1(&[p[0], p[1], p[2], rho1 as f32, rho2 as f32]);

        let mut scores = Vec::with_capacity(nq);
        let mut labels = Vec::with_capacity(nq);
        let mut start = 0;
        while start < nq {
            let take = (nq - start).min(qb);
            // pad query chunk to qb
            let mut chunk = Matrix::zeros(qb, d);
            for i in 0..take {
                chunk.row_mut(i).copy_from_slice(xq.row(start + i));
            }
            let ql = Self::pad_matrix(&chunk, qb, db)?;
            let out = self.run(
                info,
                &[xl.clone(), gl.clone(), p5.clone(), ql],
            )?;
            let s = out[0].to_vec::<f32>()?;
            let f = out[1].to_vec::<f32>()?;
            for i in 0..take {
                scores.push(s[i] as f64);
                labels.push(if f[i] > 0.0 { 1i8 } else { -1i8 });
            }
            start += take;
        }
        Ok(Some((scores, labels)))
    }

    /// KKT sweep via the `kkt_*` artifact. `kmat` must be the unpadded
    /// [n, n] Gram matrix. Returns None on bucket overflow.
    #[allow(clippy::too_many_arguments)]
    pub fn kkt_sweep(
        &self,
        kmat: &Matrix,
        gamma: &[f64],
        rho1: f64,
        rho2: f64,
        lo: f64,
        hi: f64,
        tol: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let n = kmat.rows();
        let Some(info) =
            self.manifest.select(ArtifactKind::Kkt, "any", n, 0, 0)
        else {
            return Ok(None);
        };
        let mb = info.m;
        // pad Gram to [mb, mb]
        let mut kflat = vec![0f32; mb * mb];
        for i in 0..n {
            for j in 0..n {
                kflat[i * mb + j] = kmat.get(i, j) as f32;
            }
        }
        let kl = Literal::vec1(&kflat).reshape(&[mb as i64, mb as i64])?;
        let gl = Self::pad_vec(gamma, mb);
        let p5 = Literal::vec1(&[
            rho1 as f32,
            rho2 as f32,
            lo as f32,
            hi as f32,
            tol as f32,
        ]);
        let out = self.run(info, &[kl, gl, p5])?;
        let viol = out[0].to_vec::<f32>()?;
        let fbar = out[1].to_vec::<f32>()?;
        Ok(Some((
            viol[..n].iter().map(|&v| v as f64).collect(),
            fbar[..n].iter().map(|&v| v as f64).collect(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    fn engine() -> Option<PjrtEngine> {
        let dir =
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PjrtEngine::new(dir).unwrap())
    }

    #[test]
    fn kmatrix_matches_native() {
        let Some(eng) = engine() else { return };
        let ds = SlabConfig::default().generate(100, 61);
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.01 }] {
            let got = eng.kmatrix(&ds.x, kernel).unwrap().expect("bucket fits");
            let want = kernel.gram(&ds.x, 2);
            for i in 0..100 {
                for j in 0..100 {
                    let (a, b) = (got.get(i, j), want.get(i, j));
                    assert!(
                        (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                        "({i},{j}): {a} vs {b} for {kernel:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn executable_cache_reuses_compilations() {
        let Some(eng) = engine() else { return };
        let ds = SlabConfig::default().generate(50, 62);
        eng.kmatrix(&ds.x, Kernel::Linear).unwrap();
        let c1 = eng.stats().compiles;
        eng.kmatrix(&ds.x, Kernel::Linear).unwrap();
        let c2 = eng.stats().compiles;
        assert_eq!(c1, c2, "second call must not recompile");
        assert!(eng.stats().executions >= 2);
    }

    #[test]
    fn oversize_falls_back_to_none() {
        let Some(eng) = engine() else { return };
        let ds = SlabConfig::default().generate(3000, 63); // > 2048 bucket
        assert!(eng.kmatrix(&ds.x, Kernel::Linear).unwrap().is_none());
    }
}
