//! PJRT executor thread + `Send` proxy handle.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and must stay on one
//! thread. [`PjrtProxy`] gives the multi-threaded coordinator a
//! `Send + Clone` handle: one dedicated executor thread owns the
//! [`PjrtEngine`] and serves operations over a channel (the PJRT CPU
//! client parallelizes internally, so a single dispatch thread is not
//! the bottleneck; the batcher amortizes the channel hop across whole
//! batches).
//!
//! The executor thread exits when every proxy clone has been dropped.

use std::sync::mpsc::{self, Sender};
use std::sync::Arc;

use super::pjrt::{PjrtEngine, PjrtStats};
use crate::error::Error;
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::solver::ocssvm::SlabModel;
use crate::Result;

#[allow(clippy::type_complexity)]
enum Op {
    Gram {
        x: Matrix,
        kernel: Kernel,
        reply: Sender<Result<Option<Matrix>>>,
    },
    Predict {
        model: Arc<SlabModel>,
        xq: Matrix,
        reply: Sender<Result<Option<(Vec<f64>, Vec<i8>)>>>,
    },
    Kkt {
        kmat: Matrix,
        gamma: Vec<f64>,
        rho1: f64,
        rho2: f64,
        lo: f64,
        hi: f64,
        tol: f64,
        reply: Sender<Result<Option<(Vec<f64>, Vec<f64>)>>>,
    },
    Stats {
        reply: Sender<PjrtStats>,
    },
}

/// Cloneable, thread-safe handle to the PJRT executor thread.
#[derive(Clone)]
pub struct PjrtProxy {
    tx: Sender<Op>,
}

impl PjrtProxy {
    /// Spawn the executor thread over an artifacts directory. Fails fast
    /// if the manifest cannot be loaded (checked on the caller's thread
    /// before the engine is constructed on the executor thread).
    pub fn start(artifacts_dir: impl AsRef<std::path::Path>) -> Result<PjrtProxy> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        // validate the manifest here so startup errors are synchronous
        super::manifest::Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Op>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("slabsvm-pjrt".into())
            .spawn(move || {
                let engine = match PjrtEngine::new(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(op) = rx.recv() {
                    match op {
                        Op::Gram { x, kernel, reply } => {
                            let _ = reply.send(engine.kmatrix(&x, kernel));
                        }
                        Op::Predict { model, xq, reply } => {
                            let _ = reply.send(engine.decision(
                                &model.x_sv,
                                &model.gamma,
                                model.rho1,
                                model.rho2,
                                model.kernel,
                                &xq,
                            ));
                        }
                        Op::Kkt {
                            kmat,
                            gamma,
                            rho1,
                            rho2,
                            lo,
                            hi,
                            tol,
                            reply,
                        } => {
                            let _ = reply.send(engine.kkt_sweep(
                                &kmat, &gamma, rho1, rho2, lo, hi, tol,
                            ));
                        }
                        Op::Stats { reply } => {
                            let _ = reply.send(engine.stats());
                        }
                    }
                }
            })
            .map_err(|e| Error::Pjrt(format!("cannot spawn pjrt thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Pjrt("pjrt thread died during init".into()))??;
        Ok(PjrtProxy { tx })
    }

    fn call<T>(&self, op: Op, rx: mpsc::Receiver<Result<T>>) -> Result<T> {
        self.tx
            .send(op)
            .map_err(|_| Error::Pjrt("pjrt executor thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Pjrt("pjrt executor dropped request".into()))?
    }

    /// Gram matrix (None = no bucket fits; caller falls back to native).
    pub fn gram(&self, x: &Matrix, kernel: Kernel) -> Result<Option<Matrix>> {
        let (reply, rx) = mpsc::channel();
        self.call(Op::Gram { x: x.clone(), kernel, reply }, rx)
    }

    /// Batched decision function (None = no bucket fits).
    pub fn predict(
        &self,
        model: &Arc<SlabModel>,
        xq: &Matrix,
    ) -> Result<Option<(Vec<f64>, Vec<i8>)>> {
        let (reply, rx) = mpsc::channel();
        self.call(
            Op::Predict { model: Arc::clone(model), xq: xq.clone(), reply },
            rx,
        )
    }

    /// KKT sweep (None = no bucket fits).
    #[allow(clippy::too_many_arguments)]
    pub fn kkt_sweep(
        &self,
        kmat: &Matrix,
        gamma: &[f64],
        rho1: f64,
        rho2: f64,
        lo: f64,
        hi: f64,
        tol: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let (reply, rx) = mpsc::channel();
        self.call(
            Op::Kkt {
                kmat: kmat.clone(),
                gamma: gamma.to_vec(),
                rho1,
                rho2,
                lo,
                hi,
                tol,
                reply,
            },
            rx,
        )
    }

    /// Executor-side counters.
    pub fn stats(&self) -> Result<PjrtStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Op::Stats { reply })
            .map_err(|_| Error::Pjrt("pjrt executor thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Pjrt("pjrt executor dropped request".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    fn proxy() -> Option<PjrtProxy> {
        let dir =
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(PjrtProxy::start(dir).unwrap())
    }

    #[test]
    fn proxy_gram_matches_native() {
        let Some(p) = proxy() else { return };
        let ds = SlabConfig::default().generate(64, 111);
        let got = p.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap().unwrap();
        let want = Kernel::Rbf { g: 0.01 }.gram(&ds.x, 2);
        for i in 0..64 {
            for j in 0..64 {
                assert!((got.get(i, j) - want.get(i, j)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn proxy_usable_from_many_threads() {
        let Some(p) = proxy() else { return };
        let ds = SlabConfig::default().generate(32, 112);
        let x = Arc::new(ds.x);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let x = Arc::clone(&x);
            handles.push(std::thread::spawn(move || {
                p.gram(&x, Kernel::Linear).unwrap().unwrap()
            }));
        }
        let first = handles
            .pop()
            .unwrap()
            .join()
            .unwrap();
        for h in handles {
            let k = h.join().unwrap();
            assert_eq!(k.data(), first.data());
        }
    }

    #[test]
    fn bad_dir_fails_fast() {
        assert!(PjrtProxy::start("/nonexistent").is_err());
    }
}
