//! PJRT runtime: load + execute the AOT artifacts from `make artifacts`.
//!
//! This is the request-path bridge to the Python-authored compute: the
//! JAX/Pallas graphs are lowered once to HLO text (`python/compile/aot.py`),
//! and this module loads them with the `xla` crate
//! (`PjRtClient::cpu() → HloModuleProto::from_text_file → compile →
//! execute`) — Python never runs after build time.
//!
//! * [`manifest`] — parse `artifacts/manifest.json`, shape-bucket lookup.
//! * [`pjrt`] — executable cache + typed wrappers per artifact kind
//!   (Gram matrix, batched decision function, KKT sweep) with padding to
//!   shape buckets (padded support rows carry γ = 0, making them inert).
//! * [`engine`] — `Engine`: one enum over the native (pure-rust) and
//!   PJRT paths exposing identical semantics; equivalence across the two
//!   is asserted in `rust/tests/runtime_roundtrip.rs` (experiment A3).

pub mod engine;
pub mod manifest;
pub mod pjrt;
pub mod proxy;

pub use engine::Engine;
pub use manifest::{ArtifactInfo, ArtifactKind, Manifest};
pub use pjrt::PjrtEngine;
pub use proxy::PjrtProxy;
