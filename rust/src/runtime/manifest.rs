//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! `artifacts/manifest.json` lists every lowered HLO module with its
//! entry shapes so the runtime can select shape buckets without parsing
//! HLO text. Padding contract: the runtime may execute a problem of size
//! n on any bucket with m ≥ n by zero-padding (γ = 0 on padded rows).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Kind of computation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// (x[m,d], p3) -> (K[m,m],)
    Kmatrix,
    /// (x[m,d], gamma[m], p5, xq[q,d]) -> (scores[q], labels[q])
    Decision,
    /// (K[m,m], gamma[m], p5) -> (viol[m], fbar[m])
    Kkt,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "kmatrix" => Ok(ArtifactKind::Kmatrix),
            "decision" => Ok(ArtifactKind::Decision),
            "kkt" => Ok(ArtifactKind::Kkt),
            other => Err(Error::Artifact(format!("unknown artifact kind {other}"))),
        }
    }
}

/// One lowered HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub kind: ArtifactKind,
    /// kernel family name ("linear", "rbf", ... or "any" for kkt)
    pub family: String,
    /// support-set bucket size
    pub m: usize,
    /// feature-dim bucket (0 when not applicable)
    pub d: usize,
    /// query bucket (0 when not applicable)
    pub q: usize,
    /// path to the HLO text file
    pub path: PathBuf,
}

/// Parsed manifest with bucket lookup.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactInfo>,
    /// distinct m buckets, ascending
    pub m_buckets: Vec<usize>,
    /// distinct (kind-specific) d buckets, ascending
    pub d_buckets: Vec<usize>,
    /// distinct q buckets, ascending
    pub q_buckets: Vec<usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(
            |e| {
                Error::Artifact(format!(
                    "cannot read {}/manifest.json (run `make artifacts`): {e}",
                    dir.display()
                ))
            },
        )?;
        let j = Json::parse(&text)?;
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Artifact("manifest format must be hlo-text".into()));
        }
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?;

        let mut out = Manifest::default();
        let mut mb: BTreeMap<usize, ()> = BTreeMap::new();
        let mut db: BTreeMap<usize, ()> = BTreeMap::new();
        let mut qb: BTreeMap<usize, ()> = BTreeMap::new();
        for a in arts {
            let get_s = |k: &str| a.get(k).and_then(Json::as_str);
            let get_n = |k: &str| a.get(k).and_then(Json::as_usize).unwrap_or(0);
            let kind = ArtifactKind::parse(
                get_s("kind").ok_or_else(|| Error::Artifact("missing kind".into()))?,
            )?;
            let file = get_s("file")
                .ok_or_else(|| Error::Artifact("missing file".into()))?;
            let info = ArtifactInfo {
                kind,
                family: get_s("family").unwrap_or("any").to_string(),
                m: get_n("m"),
                d: get_n("d"),
                q: get_n("q"),
                path: dir.join(file),
            };
            if !info.path.exists() {
                return Err(Error::Artifact(format!(
                    "manifest lists missing file {}",
                    info.path.display()
                )));
            }
            mb.insert(info.m, ());
            if info.d > 0 {
                db.insert(info.d, ());
            }
            if info.q > 0 {
                qb.insert(info.q, ());
            }
            out.artifacts.push(info);
        }
        out.m_buckets = mb.into_keys().collect();
        out.d_buckets = db.into_keys().collect();
        out.q_buckets = qb.into_keys().collect();
        Ok(out)
    }

    /// Smallest bucket ≥ n from a sorted bucket list.
    pub fn bucket_for(buckets: &[usize], n: usize) -> Option<usize> {
        buckets.iter().copied().find(|&b| b >= n)
    }

    /// Locate the artifact for (kind, family, exact buckets).
    pub fn find(
        &self,
        kind: ArtifactKind,
        family: &str,
        m: usize,
        d: usize,
        q: usize,
    ) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| {
            a.kind == kind
                && a.m == m
                && a.d == d
                && a.q == q
                && (a.family == family || a.family == "any")
        })
    }

    /// Pick buckets and locate an artifact for a problem of size
    /// (n, dim[, nq]). Returns None if any dimension exceeds the largest
    /// bucket (callers fall back to the native engine or chunk).
    pub fn select(
        &self,
        kind: ArtifactKind,
        family: &str,
        n: usize,
        dim: usize,
        nq: usize,
    ) -> Option<&ArtifactInfo> {
        let m = Self::bucket_for(&self.m_buckets, n)?;
        let d = if kind == ArtifactKind::Kkt {
            0
        } else {
            Self::bucket_for(&self.d_buckets, dim)?
        };
        let q = if kind == ArtifactKind::Decision {
            Self::bucket_for(&self.q_buckets, nq.min(self.max_q()?))?
        } else {
            0
        };
        self.find(kind, family, m, d, q)
    }

    /// Largest query bucket (decision requests are chunked to this).
    pub fn max_q(&self) -> Option<usize> {
        self.q_buckets.last().copied()
    }

    /// Largest m bucket.
    pub fn max_m(&self) -> Option<usize> {
        self.m_buckets.last().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 20);
        assert!(m.m_buckets.contains(&256));
        assert!(m.m_buckets.contains(&2048));
        assert!(m.q_buckets.contains(&64));
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(Manifest::bucket_for(&[256, 512, 1024], 100), Some(256));
        assert_eq!(Manifest::bucket_for(&[256, 512, 1024], 256), Some(256));
        assert_eq!(Manifest::bucket_for(&[256, 512, 1024], 257), Some(512));
        assert_eq!(Manifest::bucket_for(&[256, 512, 1024], 5000), None);
    }

    #[test]
    fn select_finds_linear_kmatrix() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let a = m.select(ArtifactKind::Kmatrix, "linear", 300, 2, 0).unwrap();
        assert_eq!(a.m, 512);
        assert_eq!(a.d, 2);
        // kkt artifacts are family-agnostic
        let k = m.select(ArtifactKind::Kkt, "rbf", 1000, 0, 0).unwrap();
        assert_eq!(k.m, 1024);
        // oversize returns None
        assert!(m.select(ArtifactKind::Kmatrix, "linear", 100_000, 2, 0).is_none());
    }

    #[test]
    fn missing_manifest_is_artifact_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
    }
}
