//! `Engine`: one interface over the native and PJRT compute paths.
//!
//! The solvers and the serving coordinator are written against this
//! enum; `--engine native|pjrt` on the CLI switches the whole stack.
//! The PJRT variant talks to the dedicated executor thread through
//! [`PjrtProxy`] (the `xla` client is not `Send`), so `Engine` itself is
//! `Send + Clone` and fans out across batcher workers. PJRT calls that
//! fall outside the artifact buckets degrade gracefully to the native
//! path (recorded in [`EngineStats::fallbacks`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::proxy::PjrtProxy;
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::solver::ocssvm::SlabModel;
use crate::util::threadpool;
use crate::Result;

/// Fallback counters.
#[derive(Debug, Default)]
pub struct EngineStats {
    /// PJRT requests served natively because no bucket fit
    pub fallbacks: AtomicU64,
}

/// Compute engine selection.
#[derive(Clone)]
pub enum Engine {
    /// pure-rust kernels (parallel, f64)
    Native,
    /// AOT artifacts on the PJRT CPU client (f32), via the executor proxy
    Pjrt(PjrtProxy, Arc<EngineStats>),
}

impl Engine {
    /// Build the PJRT variant from an artifacts directory.
    pub fn pjrt(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Ok(Engine::Pjrt(
            PjrtProxy::start(artifacts_dir)?,
            Arc::new(EngineStats::default()),
        ))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Pjrt(..) => "pjrt",
        }
    }

    fn native_predict(model: &SlabModel, xq: &Matrix) -> (Vec<f64>, Vec<i8>) {
        let scores = model.scores(xq);
        let labels = scores
            .iter()
            .map(|&s| {
                if (s - model.rho1) * (model.rho2 - s) >= 0.0 {
                    1i8
                } else {
                    -1i8
                }
            })
            .collect();
        (scores, labels)
    }

    /// Full Gram matrix.
    pub fn gram(&self, x: &Matrix, kernel: Kernel) -> Result<Matrix> {
        match self {
            Engine::Native => Ok(kernel.gram(x, threadpool::default_threads())),
            Engine::Pjrt(proxy, stats) => match proxy.gram(x, kernel)? {
                Some(k) => Ok(k),
                None => {
                    stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                    Ok(kernel.gram(x, threadpool::default_threads()))
                }
            },
        }
    }

    /// Batched model scoring: (scores, labels) for a query matrix.
    pub fn predict(
        &self,
        model: &Arc<SlabModel>,
        xq: &Matrix,
    ) -> Result<(Vec<f64>, Vec<i8>)> {
        match self {
            Engine::Native => Ok(Self::native_predict(model, xq)),
            Engine::Pjrt(proxy, stats) => match proxy.predict(model, xq)? {
                Some(r) => Ok(r),
                None => {
                    stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                    Ok(Self::native_predict(model, xq))
                }
            },
        }
    }

    /// Number of PJRT fallbacks so far (0 for native).
    pub fn fallbacks(&self) -> u64 {
        match self {
            Engine::Native => 0,
            Engine::Pjrt(_, stats) => stats.fallbacks.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::solver::api::Trainer;

    #[test]
    fn native_gram_works() {
        let ds = SlabConfig::default().generate(50, 71);
        let k = Engine::Native.gram(&ds.x, Kernel::Rbf { g: 0.1 }).unwrap();
        assert_eq!(k.rows(), 50);
        assert!((k.get(7, 7) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn native_predict_matches_model() {
        let ds = SlabConfig::default().generate(120, 72);
        let model = Trainer::default().kernel(Kernel::Linear).fit(&ds.x).unwrap().model;
        let model = Arc::new(model);
        let q = SlabConfig::default().generate_eval(30, 30, 73);
        let (scores, labels) = Engine::Native.predict(&model, &q.x).unwrap();
        let want = model.predict(&q.x);
        assert_eq!(labels, want);
        for (i, &s) in scores.iter().enumerate() {
            assert!((s - model.score(q.x.row(i))).abs() < 1e-9);
        }
    }

    #[test]
    fn engine_is_send_and_clone() {
        fn assert_send<T: Send + Clone>() {}
        assert_send::<Engine>();
    }
}
