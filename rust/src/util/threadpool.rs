//! Scoped fork-join parallelism helper (rayon-lite).
//!
//! [`parallel_chunks`] splits an index range into contiguous chunks and
//! runs one scoped thread per chunk — used by the parallel Gram builder
//! and the bench workload generators. std::thread::scope keeps borrows
//! safe without 'static bounds.

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks, in parallel. `f` must be Sync (it is shared across
/// workers); interior mutability of outputs is the caller's business
/// (e.g. disjoint &mut slices via split_at_mut, or atomics).
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Map `f` over disjoint mutable row-chunks of `out` (len n*stride),
/// in parallel: each worker gets rows [start, end) as one &mut slice.
pub fn parallel_rows<F>(out: &mut [f64], stride: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    let n = if stride == 0 { 0 } else { out.len() / stride };
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row = 0;
        for _ in 0..threads {
            let take = chunk.min(rest.len() / stride).min(n - row);
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take * stride);
            rest = tail;
            let f = &f;
            let start_row = row;
            s.spawn(move || f(start_row, head));
            row += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 1003;
        let counts: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 7, |s, e| {
            for i in s..e {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let mut hit = false;
        parallel_chunks(10, 1, |s, e| {
            assert_eq!((s, e), (0, 10));
            // closure is Fn so no captures mutation; use a raw check
            let _ = &hit;
        });
        hit = true;
        assert!(hit);
    }

    #[test]
    fn parallel_rows_disjoint() {
        let stride = 8;
        let n = 37;
        let mut out = vec![0.0; n * stride];
        parallel_rows(&mut out, stride, 5, |start_row, rows| {
            for (r, row) in rows.chunks_mut(stride).enumerate() {
                for v in row.iter_mut() {
                    *v = (start_row + r) as f64;
                }
            }
        });
        for r in 0..n {
            for c in 0..stride {
                assert_eq!(out[r * stride + c], r as f64);
            }
        }
    }

    #[test]
    fn zero_n_is_fine() {
        parallel_chunks(0, 4, |s, e| assert_eq!(s, e));
    }
}
