//! In-tree substrates replacing ecosystem crates (offline build).
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates are replaced by small,
//! well-tested local implementations:
//!
//! | would-be crate | local module |
//! |---|---|
//! | `rand` / `rand_distr` | [`rng`] — xoshiro256++, normal/laplace/uniform |
//! | `serde_json` | [`json`] — minimal JSON value parser/emitter |
//! | `clap` | [`cli`] — declarative-ish argument parser |
//! | `log` + `env_logger` | [`logging`] — leveled stderr logger |
//! | `rayon` (scoped pools) | [`threadpool`] — scoped fork-join helper |

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;
