//! Deterministic pseudo-random numbers + sampling distributions.
//!
//! xoshiro256++ (Blackman & Vigna) seeded via SplitMix64, plus the
//! distributions the data generators and tests need: uniform, standard
//! normal (Marsaglia polar), Laplace (inverse CDF) and shuffling.
//! Deterministic across runs and platforms — every experiment in
//! DESIGN.md (experiment index) records its seed.

/// xoshiro256++ PRNG. Not cryptographic; fast and high-quality for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from the polar method
    spare: Option<f64>,
}

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Marsaglia's polar method (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Laplace(0, b) via inverse CDF — heavy-ish tails for the noisy slab
    /// band (DESIGN.md §Substitutions).
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Rng::new(9);
        let b = 0.7;
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.laplace(b)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02);
        // Var[Laplace(0,b)] = 2 b^2
        assert!((var - 2.0 * b * b).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(29);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }
}
