//! Tiny declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and per-subcommand help text. The `slabsvm` binary defines one
//! [`ArgSpec`] per subcommand and parses with [`parse_args`].

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declares one accepted option.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    /// long name without the leading `--`
    pub name: &'static str,
    /// help text
    pub help: &'static str,
    /// if false, the option is a boolean flag (no value)
    pub takes_value: bool,
    /// default value (None = absent unless provided)
    pub default: Option<&'static str>,
}

impl ArgSpec {
    pub fn opt(name: &'static str, default: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, takes_value: true, default: Some(default) }
    }
    pub fn req(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, takes_value: true, default: None }
    }
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, takes_value: false, default: None }
    }
}

/// Parsed arguments: options by name + positional extras.
#[derive(Debug, Default)]
pub struct Parsed {
    vals: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.vals.get(name).map(|s| s.as_str())
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self
            .get(name)
            .ok_or_else(|| Error::config(format!("missing --{name}")))?;
        v.parse()
            .map_err(|_| Error::config(format!("--{name}: not a number: {v}")))
    }
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self
            .get(name)
            .ok_or_else(|| Error::config(format!("missing --{name}")))?;
        v.parse()
            .map_err(|_| Error::config(format!("--{name}: not an integer: {v}")))
    }
    pub fn get_str(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::config(format!("missing --{name}")))
    }
}

/// Parse `args` (without argv[0]/subcommand) against `spec`.
pub fn parse_args(spec: &[ArgSpec], args: &[String]) -> Result<Parsed> {
    let mut out = Parsed::default();
    // seed defaults
    for s in spec {
        if let Some(d) = s.default {
            out.vals.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline_val) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let s = spec
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| Error::config(format!("unknown option --{name}")))?;
            if s.takes_value {
                let v = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| {
                                Error::config(format!("--{name} needs a value"))
                            })?
                    }
                };
                out.vals.insert(name.to_string(), v);
            } else {
                if inline_val.is_some() {
                    return Err(Error::config(format!("--{name} takes no value")));
                }
                out.flags.push(name.to_string());
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

/// Render help text for a subcommand.
pub fn render_help(cmd: &str, about: &str, spec: &[ArgSpec]) -> String {
    let mut s = format!("slabsvm {cmd} — {about}\n\noptions:\n");
    for a in spec {
        let meta = if a.takes_value { " <v>" } else { "" };
        let def = match a.default {
            Some(d) => format!(" [default: {d}]"),
            None => String::new(),
        };
        s.push_str(&format!("  --{}{meta}\t{}{def}\n", a.name, a.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<ArgSpec> {
        vec![
            ArgSpec::opt("size", "1000", "dataset size"),
            ArgSpec::req("out", "output path"),
            ArgSpec::flag("verbose", "chatty"),
        ]
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = parse_args(&spec(), &s(&["--out", "x.csv"])).unwrap();
        assert_eq!(p.get_usize("size").unwrap(), 1000);
        assert_eq!(p.get("out"), Some("x.csv"));
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let p = parse_args(&spec(), &s(&["--size=42", "--out=o"])).unwrap();
        assert_eq!(p.get_usize("size").unwrap(), 42);
    }

    #[test]
    fn flags_and_positional() {
        let p =
            parse_args(&spec(), &s(&["--verbose", "pos1", "--out", "o", "pos2"]))
                .unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse_args(&spec(), &s(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse_args(&spec(), &s(&["--size"])).is_err());
    }

    #[test]
    fn typed_errors() {
        let p = parse_args(&spec(), &s(&["--size", "abc", "--out", "o"])).unwrap();
        assert!(p.get_f64("size").is_err());
        assert!(p.get_usize("size").is_err());
    }
}
