//! Minimal leveled stderr logger (log/env_logger substitute).
//!
//! Level comes from `SLABSVM_LOG` (error|warn|info|debug|trace) or the
//! `--verbose` CLI flag. Timestamps are monotonic seconds since logger
//! init, which keeps bench logs diffable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize from the environment; call once at process start.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("SLABSVM_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        });
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Core log call; use the macros below instead.
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target,
                                   format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
