//! Minimal leveled stderr logger (log/env_logger substitute).
//!
//! Level comes from `SLABSVM_LOG` (error|warn|info|debug|trace) or the
//! `--verbose` CLI flag. Timestamps are monotonic seconds since logger
//! init, which keeps bench logs diffable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize from the environment; call once at process start.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("SLABSVM_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            other => {
                // fall back loudly: a typo'd filter that silently
                // reverts to info reads as "debug logging is broken"
                log(
                    Level::Warn,
                    "logging",
                    format_args!(
                        "unknown SLABSVM_LOG value {other:?}; using info \
                         (expected error|warn|info|debug|trace)"
                    ),
                );
                Level::Info
            }
        });
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Core log call; use the macros below instead.
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    log_with_trace(l, target, 0, msg);
}

/// [`log`] with a span-trace correlation id (`trace=<id>` suffix; 0 =
/// untraced, printed identically to [`log`]). The `trace: <id>,` macro
/// arms route here so log lines and `obs::trace` spans join on the id.
pub fn log_with_trace(
    l: Level,
    target: &str,
    trace_id: u64,
    msg: std::fmt::Arguments<'_>,
) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    if trace_id == 0 {
        eprintln!("[{t:9.3}s {tag} {target}] {msg}");
    } else {
        eprintln!("[{t:9.3}s {tag} {target}] {msg} trace={trace_id}");
    }
}

#[macro_export]
macro_rules! log_error {
    (trace: $tid:expr, $target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_with_trace(
            $crate::util::logging::Level::Error, $target, $tid,
            format_args!($($arg)*))
    };
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target,
                                   format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_warn {
    (trace: $tid:expr, $target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_with_trace(
            $crate::util::logging::Level::Warn, $target, $tid,
            format_args!($($arg)*))
    };
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_info {
    (trace: $tid:expr, $target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_with_trace(
            $crate::util::logging::Level::Info, $target, $tid,
            format_args!($($arg)*))
    };
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_debug {
    (trace: $tid:expr, $target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_with_trace(
            $crate::util::logging::Level::Debug, $target, $tid,
            format_args!($($arg)*))
    };
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_macros_compile_and_gate() {
        init();
        set_level(Level::Info);
        // the trace: arms must accept both traced and untraced calls
        crate::log_info!(trace: 42, "test", "traced line {}", 1);
        crate::log_info!("test", "untraced line {}", 2);
        log_with_trace(Level::Debug, "test", 7, format_args!("gated out"));
    }

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
