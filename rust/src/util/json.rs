//! Minimal JSON parser + emitter (serde_json substitute).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to read `artifacts/manifest.json`
//! written by `python/compile/aot.py` and to emit machine-readable
//! results from the bench harness.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so emission
/// is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Data(format!(
                "trailing JSON content at byte {}",
                p.i
            )));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type mismatch) -------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // ---- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    /// Compact canonical emission.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Data(format!("JSON parse error at byte {}: {msg}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        self.b.get(self.i + 2..self.i + 6).ok_or_else(
                                            || self.err("bad surrogate pair"),
                                        )?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad pair"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad pair"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 for multi-byte chars.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn reads_real_manifest_shape() {
        let src = r#"{"format":"hlo-text","artifacts":[
            {"kind":"kmatrix","family":"linear","m":256,"d":2,
             "inputs":[[256,2],[3]],"outputs":[[256,256]],
             "file":"kmatrix_linear_m256_d2.hlo.txt","bytes":1234}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("m").unwrap().as_usize(), Some(256));
        assert_eq!(a.get("kind").unwrap().as_str(), Some("kmatrix"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo wörld ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ✓");
    }
}
