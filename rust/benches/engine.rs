//! Bench: **A3** — native vs PJRT engine on the batch compute paths.
//!
//! Measures the two engines on (a) full-Gram precompute and (b) batch
//! decision-function scoring, across shape buckets. The PJRT path runs
//! the AOT-lowered Pallas kernels through the XLA CPU client; interpret-
//! mode Pallas lowers to a sequential grid loop, so native wins on CPU —
//! the bench quantifies the gap and checks numerical agreement first.
//! (On a real TPU the same artifacts lower to MXU matmuls; see DESIGN.md
//! §Hardware-Adaptation / §Perf for the VMEM/MXU analysis.)
//!
//! Also measures **K1** — the lane-blocked row kernel against a naive
//! per-element `eval` loop, with a perf floor: the blocked path must not
//! be materially slower than scalar (asserted; nonzero exit on failure).
//! K1 needs no artifacts and always runs.
//!
//! Requires `make artifacts` for A3. Run: `cargo bench --bench engine`

use std::sync::Arc;
use std::time::Instant;

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::{SolverKind, Trainer};

/// K1 — blocked vs scalar RBF row build over an m×d design. Returns
/// (blocked_median_s, scalar_median_s) for the perf-floor assertion.
fn row_kernel_bench(bench: &mut Bench) -> (f64, f64) {
    let m = 1024usize;
    let ds = SlabConfig::default().generate(m, 4242);
    let kern = Kernel::Rbf { g: 0.01 };
    let q: Vec<f64> = ds.x.row(0).to_vec();
    let reps = 32usize;

    let mut out = vec![0.0; m];
    let blocked = bench
        .run("rowkernel-blocked/m=1024", || {
            let t0 = Instant::now();
            for _ in 0..reps {
                kern.row(&ds.x, &q, &mut out);
                std::hint::black_box(&out);
            }
            let dt = t0.elapsed().as_secs_f64();
            vec![
                ("kernel_rows_per_s".into(), reps as f64 / dt),
                ("ns_per_row".into(), dt * 1e9 / reps as f64),
                ("checksum".into(), out.iter().sum()),
            ]
        })
        .median();

    let mut out2 = vec![0.0; m];
    let scalar = bench
        .run("rowkernel-scalar/m=1024", || {
            let t0 = Instant::now();
            for _ in 0..reps {
                for (j, o) in out2.iter_mut().enumerate() {
                    *o = kern.eval(ds.x.row(j), &q);
                }
                std::hint::black_box(&out2);
            }
            let dt = t0.elapsed().as_secs_f64();
            vec![
                ("kernel_rows_per_s".into(), reps as f64 / dt),
                ("ns_per_row".into(), dt * 1e9 / reps as f64),
                ("checksum".into(), out2.iter().sum()),
            ]
        })
        .median();

    assert_eq!(
        out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        out2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "blocked row must be bitwise-identical to the scalar eval loop"
    );
    (blocked, scalar)
}

fn main() {
    let mut bench = Bench::from_env();

    // ---- K1: row-kernel microbench + perf floor -----------------------
    let (blocked_s, scalar_s) = row_kernel_bench(&mut bench);
    println!(
        "row kernel: blocked {blocked_s:.6}s vs scalar {scalar_s:.6}s \
         per sample ({:.2}x)",
        scalar_s / blocked_s.max(1e-12)
    );
    // perf floor: the restructured path exists to be vectorizable; it
    // must never regress below the naive loop (slack for timer noise in
    // the 1-sample CI smoke run)
    assert!(
        blocked_s <= scalar_s * 1.25,
        "perf floor violated: blocked row kernel {blocked_s:.6}s > \
         1.25 x scalar {scalar_s:.6}s"
    );

    let Ok(pjrt) = Engine::pjrt("artifacts") else {
        eprintln!("artifacts missing — run `make artifacts` first; skipping");
        bench.report("K1 — blocked row kernel (A3 skipped: no artifacts)");
        return;
    };
    let native = Engine::Native;

    // ---- numerical agreement gate ------------------------------------
    {
        let ds = SlabConfig::default().generate(200, 61);
        let kn = native.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap();
        let kp = pjrt.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap();
        let mut max_err = 0.0f64;
        for i in 0..200 {
            for j in 0..200 {
                max_err = max_err.max((kn.get(i, j) - kp.get(i, j)).abs());
            }
        }
        assert!(max_err < 1e-3, "engines disagree: {max_err}");
        println!("engine agreement: max |Δgram| = {max_err:.2e} (f32 vs f64)");
    }

    // ---- (a) Gram precompute ------------------------------------------
    for &m in &[256usize, 1024, 2048] {
        let ds = SlabConfig::default().generate(m, 6000 + m as u64);
        for (name, eng) in [("native", &native), ("pjrt", &pjrt)] {
            bench.run(&format!("gram-{name}/m={m}"), || {
                let k = eng.gram(&ds.x, Kernel::Linear).expect("gram");
                vec![("checksum".into(), k.get(0, 0))]
            });
        }
    }

    // ---- (b) batch scoring ---------------------------------------------
    let train = SlabConfig::default().generate(1000, 42);
    let model = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .fit(&train.x)
        .unwrap()
        .model;
    let model = Arc::new(model);
    for &q in &[64usize, 256, 1024] {
        let queries = SlabConfig::default().generate_eval(q / 2, q / 2, 9);
        for (name, eng) in [("native", &native), ("pjrt", &pjrt)] {
            bench.run(&format!("score-{name}/q={q}"), || {
                let (s, _) = eng.predict(&model, &queries.x).expect("predict");
                vec![("throughput_qps".into(), 0.0), ("s0".into(), s[0])]
            });
        }
    }
    bench.report("A3 — native vs PJRT engine (Gram build + batch scoring)");
    println!("\nnote: pjrt runs interpret-mode Pallas (sequential grid) on the CPU client;");
    println!("the same artifacts target MXU matmuls on real TPUs (DESIGN.md §Perf).");
}
