//! Bench: **A3** — native vs PJRT engine on the batch compute paths.
//!
//! Measures the two engines on (a) full-Gram precompute and (b) batch
//! decision-function scoring, across shape buckets. The PJRT path runs
//! the AOT-lowered Pallas kernels through the XLA CPU client; interpret-
//! mode Pallas lowers to a sequential grid loop, so native wins on CPU —
//! the bench quantifies the gap and checks numerical agreement first.
//! (On a real TPU the same artifacts lower to MXU matmuls; see DESIGN.md
//! §Hardware-Adaptation / §Perf for the VMEM/MXU analysis.)
//!
//! Requires `make artifacts`. Run: `cargo bench --bench engine`

use std::sync::Arc;

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::{SolverKind, Trainer};

fn main() {
    let Ok(pjrt) = Engine::pjrt("artifacts") else {
        eprintln!("artifacts missing — run `make artifacts` first; skipping");
        return;
    };
    let native = Engine::Native;
    let mut bench = Bench::from_env();

    // ---- numerical agreement gate ------------------------------------
    {
        let ds = SlabConfig::default().generate(200, 61);
        let kn = native.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap();
        let kp = pjrt.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap();
        let mut max_err = 0.0f64;
        for i in 0..200 {
            for j in 0..200 {
                max_err = max_err.max((kn.get(i, j) - kp.get(i, j)).abs());
            }
        }
        assert!(max_err < 1e-3, "engines disagree: {max_err}");
        println!("engine agreement: max |Δgram| = {max_err:.2e} (f32 vs f64)");
    }

    // ---- (a) Gram precompute ------------------------------------------
    for &m in &[256usize, 1024, 2048] {
        let ds = SlabConfig::default().generate(m, 6000 + m as u64);
        for (name, eng) in [("native", &native), ("pjrt", &pjrt)] {
            bench.run(&format!("gram-{name}/m={m}"), || {
                let k = eng.gram(&ds.x, Kernel::Linear).expect("gram");
                vec![("checksum".into(), k.get(0, 0))]
            });
        }
    }

    // ---- (b) batch scoring ---------------------------------------------
    let train = SlabConfig::default().generate(1000, 42);
    let model = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .fit(&train.x)
        .unwrap()
        .model;
    let model = Arc::new(model);
    for &q in &[64usize, 256, 1024] {
        let queries = SlabConfig::default().generate_eval(q / 2, q / 2, 9);
        for (name, eng) in [("native", &native), ("pjrt", &pjrt)] {
            bench.run(&format!("score-{name}/q={q}"), || {
                let (s, _) = eng.predict(&model, &queries.x).expect("predict");
                vec![("throughput_qps".into(), 0.0), ("s0".into(), s[0])]
            });
        }
    }
    bench.report("A3 — native vs PJRT engine (Gram build + batch scoring)");
    println!("\nnote: pjrt runs interpret-mode Pallas (sequential grid) on the CPU client;");
    println!("the same artifacts target MXU matmuls on real TPUs (DESIGN.md §Perf).");
}
