//! Bench: **A3** — native vs PJRT engine on the batch compute paths.
//!
//! Measures the two engines on (a) full-Gram precompute and (b) batch
//! decision-function scoring, across shape buckets. The PJRT path runs
//! the AOT-lowered Pallas kernels through the XLA CPU client; interpret-
//! mode Pallas lowers to a sequential grid loop, so native wins on CPU —
//! the bench quantifies the gap and checks numerical agreement first.
//! (On a real TPU the same artifacts lower to MXU matmuls; see DESIGN.md
//! §Hardware-Adaptation / §Perf for the VMEM/MXU analysis.)
//!
//! Also measures **K1** — the lane-blocked row kernel against a naive
//! per-element `eval` loop, with a perf floor: the blocked path must not
//! be materially slower than scalar (asserted; nonzero exit on failure).
//! K1 needs no artifacts and always runs.
//!
//! And **KA1** — the approximate feature-map engines (DESIGN.md §10):
//! batch fit time + AUC gap vs the exact SMO across a lifted-dimension
//! sweep, streaming absorb cost at a window the exact engine's O(m²)
//! Gram could never hold, and a scoring m-independence floor (the
//! lifted score is O(d·D); doubling the resident count must not move
//! it — asserted in-binary). KA1 needs no artifacts and always runs.
//!
//! Requires `make artifacts` for A3. Run: `cargo bench --bench engine`

use std::sync::Arc;
use std::time::Instant;

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::featmap::EngineKind;
use slabsvm::kernel::Kernel;
use slabsvm::metrics::roc_auc;
use slabsvm::runtime::Engine;
use slabsvm::solver::{SolverKind, Trainer};
use slabsvm::stream::{ApproxIncremental, IncrementalConfig};

/// KA1 — approximate-engine sweep: fit/AUC across lifted dimensions,
/// absorb cost at exact-infeasible window sizes, scoring m-independence.
fn approx_engine_bench(bench: &mut Bench, fast: bool) {
    let kernel = Kernel::Rbf { g: 0.5 };
    let n_train = if fast { 400 } else { 4000 };
    let dims: &[usize] = if fast { &[32, 64] } else { &[64, 256, 1024] };

    // exact baseline at Table-1 scale (the AUC yardstick)
    let train = SlabConfig::default().generate(n_train, 71);
    let eval = SlabConfig::default().generate_eval(500, 500, 72);
    let exact = Trainer::new(SolverKind::Smo)
        .kernel(kernel)
        .fit(&train.x)
        .expect("exact fit")
        .model;
    let exact_scores: Vec<f64> =
        (0..eval.x.rows()).map(|i| exact.score(eval.x.row(i))).collect();
    let exact_auc = roc_auc(&eval.y, &exact_scores);

    for engine in [EngineKind::Nystroem, EngineKind::Rff] {
        for &d in dims {
            bench.run(&format!("approx-fit/{engine}/D={d}"), || {
                let t0 = Instant::now();
                let model = Trainer::new(SolverKind::Approx)
                    .kernel(kernel)
                    .engine(engine)
                    .features(d)
                    .fit(&train.x)
                    .expect("approx fit")
                    .model;
                let fit_s = t0.elapsed().as_secs_f64();
                let scores: Vec<f64> = (0..eval.x.rows())
                    .map(|i| model.score(eval.x.row(i)))
                    .collect();
                let auc = roc_auc(&eval.y, &scores);
                vec![
                    ("fit_s".into(), fit_s),
                    ("features_d".into(), d as f64),
                    ("auc".into(), auc),
                    ("auc_gap".into(), (exact_auc - auc).abs()),
                ]
            });
        }
    }

    // ---- streaming absorb at a window exact cannot hold ---------------
    // window 10^5: the exact engine's Gram alone would be 8·10^10 bytes;
    // the lifted engine absorbs in O(D) regardless
    let window = if fast { 2_000 } else { 100_000 };
    let d_stream = 64usize;
    let stream_cfg = |engine| IncrementalConfig {
        engine,
        features: d_stream,
        ..Default::default()
    };
    let feed = SlabConfig::default().generate(window, 73);
    for engine in [EngineKind::Nystroem, EngineKind::Rff] {
        bench.run(&format!("approx-absorb/{engine}/window={window}"), || {
            let mut inc = ApproxIncremental::new(
                kernel,
                window,
                feed.x.cols(),
                stream_cfg(engine),
            );
            let t0 = Instant::now();
            for i in 0..feed.x.rows() {
                inc.push(feed.x.row(i)).expect("absorb");
            }
            let dt = t0.elapsed().as_secs_f64();
            vec![
                ("ns_per_absorb".into(), dt * 1e9 / feed.x.rows() as f64),
                ("features_d".into(), d_stream as f64),
                ("resident".into(), inc.len() as f64),
            ]
        });
    }

    // ---- scoring m-independence floor ----------------------------------
    // the lifted score is one D-dim dot product; a 10-100x bigger
    // resident set must not change its cost (generous slack for CI
    // timer noise on the 1-sample smoke run)
    let (m_small, m_big) = if fast { (256, 2_000) } else { (2_000, 20_000) };
    let queries = SlabConfig::default().generate(512, 74);
    let mut per_score = [0.0f64; 2];
    for (slot, &m) in [m_small, m_big].iter().enumerate() {
        let data = SlabConfig::default().generate(m, 75);
        let mut inc = ApproxIncremental::new(
            kernel,
            m,
            data.x.cols(),
            stream_cfg(EngineKind::Rff),
        );
        for i in 0..m {
            inc.push(data.x.row(i)).expect("absorb");
        }
        let s = bench
            .run(&format!("approx-score/rff/m={m}"), || {
                let reps = 8usize;
                let t0 = Instant::now();
                let mut acc = 0.0;
                for _ in 0..reps {
                    for qi in 0..queries.x.rows() {
                        acc += inc.score(queries.x.row(qi));
                    }
                }
                std::hint::black_box(acc);
                let dt = t0.elapsed().as_secs_f64();
                let per = dt * 1e9 / (reps * queries.x.rows()) as f64;
                vec![
                    ("ns_per_score".into(), per),
                    ("features_d".into(), d_stream as f64),
                ]
            })
            .median();
        per_score[slot] = s;
    }
    let ratio = per_score[1] / per_score[0].max(1e-12);
    println!(
        "approx scoring: {m_small} residents {:.6}s vs {m_big} residents          {:.6}s per batch ({ratio:.2}x)",
        per_score[0], per_score[1]
    );
    assert!(
        ratio <= 3.0,
        "m-independence floor violated: scoring at m={m_big} is          {ratio:.2}x scoring at m={m_small} (lifted scores must not          scale with the resident count)"
    );
}

/// K1 — blocked vs scalar RBF row build over an m×d design. Returns
/// (blocked_median_s, scalar_median_s) for the perf-floor assertion.
fn row_kernel_bench(bench: &mut Bench) -> (f64, f64) {
    let m = 1024usize;
    let ds = SlabConfig::default().generate(m, 4242);
    let kern = Kernel::Rbf { g: 0.01 };
    let q: Vec<f64> = ds.x.row(0).to_vec();
    let reps = 32usize;

    let mut out = vec![0.0; m];
    let blocked = bench
        .run("rowkernel-blocked/m=1024", || {
            let t0 = Instant::now();
            for _ in 0..reps {
                kern.row(&ds.x, &q, &mut out);
                std::hint::black_box(&out);
            }
            let dt = t0.elapsed().as_secs_f64();
            vec![
                ("kernel_rows_per_s".into(), reps as f64 / dt),
                ("ns_per_row".into(), dt * 1e9 / reps as f64),
                ("checksum".into(), out.iter().sum()),
            ]
        })
        .median();

    let mut out2 = vec![0.0; m];
    let scalar = bench
        .run("rowkernel-scalar/m=1024", || {
            let t0 = Instant::now();
            for _ in 0..reps {
                for (j, o) in out2.iter_mut().enumerate() {
                    *o = kern.eval(ds.x.row(j), &q);
                }
                std::hint::black_box(&out2);
            }
            let dt = t0.elapsed().as_secs_f64();
            vec![
                ("kernel_rows_per_s".into(), reps as f64 / dt),
                ("ns_per_row".into(), dt * 1e9 / reps as f64),
                ("checksum".into(), out2.iter().sum()),
            ]
        })
        .median();

    assert_eq!(
        out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        out2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "blocked row must be bitwise-identical to the scalar eval loop"
    );
    (blocked, scalar)
}

fn main() {
    let mut bench = Bench::from_env();
    let fast = std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1");

    // ---- K1: row-kernel microbench + perf floor -----------------------
    let (blocked_s, scalar_s) = row_kernel_bench(&mut bench);
    println!(
        "row kernel: blocked {blocked_s:.6}s vs scalar {scalar_s:.6}s \
         per sample ({:.2}x)",
        scalar_s / blocked_s.max(1e-12)
    );
    // perf floor: the restructured path exists to be vectorizable; it
    // must never regress below the naive loop (slack for timer noise in
    // the 1-sample CI smoke run)
    assert!(
        blocked_s <= scalar_s * 1.25,
        "perf floor violated: blocked row kernel {blocked_s:.6}s > \
         1.25 x scalar {scalar_s:.6}s"
    );

    // ---- KA1: approx engines (no artifacts needed) --------------------
    approx_engine_bench(&mut bench, fast);

    let Ok(pjrt) = Engine::pjrt("artifacts") else {
        eprintln!("artifacts missing — run `make artifacts` first; skipping");
        bench.report(
            "K1 row kernel + KA1 approx engines (A3 skipped: no artifacts)",
        );
        return;
    };
    let native = Engine::Native;

    // ---- numerical agreement gate ------------------------------------
    {
        let ds = SlabConfig::default().generate(200, 61);
        let kn = native.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap();
        let kp = pjrt.gram(&ds.x, Kernel::Rbf { g: 0.01 }).unwrap();
        let mut max_err = 0.0f64;
        for i in 0..200 {
            for j in 0..200 {
                max_err = max_err.max((kn.get(i, j) - kp.get(i, j)).abs());
            }
        }
        assert!(max_err < 1e-3, "engines disagree: {max_err}");
        println!("engine agreement: max |Δgram| = {max_err:.2e} (f32 vs f64)");
    }

    // ---- (a) Gram precompute ------------------------------------------
    for &m in &[256usize, 1024, 2048] {
        let ds = SlabConfig::default().generate(m, 6000 + m as u64);
        for (name, eng) in [("native", &native), ("pjrt", &pjrt)] {
            bench.run(&format!("gram-{name}/m={m}"), || {
                let k = eng.gram(&ds.x, Kernel::Linear).expect("gram");
                vec![("checksum".into(), k.get(0, 0))]
            });
        }
    }

    // ---- (b) batch scoring ---------------------------------------------
    let train = SlabConfig::default().generate(1000, 42);
    let model = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .fit(&train.x)
        .unwrap()
        .model;
    let model = Arc::new(model);
    for &q in &[64usize, 256, 1024] {
        let queries = SlabConfig::default().generate_eval(q / 2, q / 2, 9);
        for (name, eng) in [("native", &native), ("pjrt", &pjrt)] {
            bench.run(&format!("score-{name}/q={q}"), || {
                let (s, _) = eng.predict(&model, &queries.x).expect("predict");
                vec![("throughput_qps".into(), 0.0), ("s0".into(), s[0])]
            });
        }
    }
    bench.report(
        "A3 native vs PJRT + K1 row kernel + KA1 approx engines",
    );
    println!("\nnote: pjrt runs interpret-mode Pallas (sequential grid) on the CPU client;");
    println!("the same artifacts target MXU matmuls on real TPUs (DESIGN.md §Perf).");
}
