//! Bench: **A1** — working-set selection heuristic ablation.
//!
//! The paper's §3.2 heuristic (first choice max |f̄| over violators,
//! second choice max |f̄_b − f̄_a|) vs the classic max-violation rule vs
//! uniformly random violator selection. All three must reach the same
//! objective (asserted); the metric is iterations-to-converge and
//! wall-clock. This quantifies how much the paper's heuristic actually
//! buys — its §3.2 is the paper's only algorithmic novelty beyond the
//! OCSVM SMO recipe.
//!
//! Run: `cargo bench --bench ablation_heuristic`

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::smo::{train_full, SmoParams};
use slabsvm::solver::Heuristic;

fn main() {
    let mut bench = Bench::from_env();
    let heuristics = [
        Heuristic::PaperMaxFbar,
        Heuristic::MaxViolation,
        Heuristic::RandomViolator,
        Heuristic::SecondOrder,
    ];

    for &m in &[500usize, 2000] {
        let ds = SlabConfig::default().generate(m, 4000 + m as u64);
        let mut objectives = Vec::new();
        for h in heuristics {
            let params = SmoParams { heuristic: h, ..Default::default() };
            bench.run(&format!("{}/m={m}", h.name()), || {
                let (_, out) =
                    train_full(&ds.x, Kernel::Linear, &params).expect("train");
                objectives.push(out.stats.objective);
                vec![
                    ("iterations".into(), out.stats.iterations as f64),
                    ("objective".into(), out.stats.objective),
                ]
            });
        }
        // shrinking ablation on the paper heuristic
        let params = SmoParams {
            shrinking: false,
            ..Default::default()
        };
        bench.run(&format!("paper-no-shrink/m={m}"), || {
            let (_, out) =
                train_full(&ds.x, Kernel::Linear, &params).expect("train");
            objectives.push(out.stats.objective);
            vec![
                ("iterations".into(), out.stats.iterations as f64),
                ("objective".into(), out.stats.objective),
            ]
        });
        // all heuristics must land on the same optimum
        let lo = objectives.iter().cloned().fold(f64::MAX, f64::min);
        let hi = objectives.iter().cloned().fold(f64::MIN, f64::max);
        assert!(
            hi - lo < 1e-3 * hi.abs().max(1e-9),
            "objectives diverge at m={m}: [{lo}, {hi}]"
        );
    }
    bench.report("A1 — working-set heuristic ablation (same optimum, different effort)");
}
