//! Bench: **A1** — working-set selection heuristic ablation.
//!
//! The paper's §3.2 heuristic (first choice max |f̄| over violators,
//! second choice max |f̄_b − f̄_a|) vs the classic max-violation rule vs
//! uniformly random violator selection. All four must reach the same
//! objective (asserted); the metric is iterations-to-converge and
//! wall-clock. This quantifies how much the paper's heuristic actually
//! buys — its §3.2 is the paper's only algorithmic novelty beyond the
//! OCSVM SMO recipe.
//!
//! Run: `cargo bench --bench ablation_heuristic`

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{Heuristic, SolverKind, Trainer};

fn main() {
    let mut bench = Bench::from_env();

    for &m in &[500usize, 2000] {
        let ds = SlabConfig::default().generate(m, 4000 + m as u64);
        let mut objectives = Vec::new();
        for h in Heuristic::ALL {
            let trainer = Trainer::new(SolverKind::Smo)
                .kernel(Kernel::Linear)
                .heuristic(h);
            bench.run(&format!("{h}/m={m}"), || {
                let report = trainer.fit(&ds.x).expect("train");
                objectives.push(report.stats.objective);
                vec![
                    ("iterations".into(), report.stats.iterations as f64),
                    ("objective".into(), report.stats.objective),
                ]
            });
        }
        // shrinking ablation on the paper heuristic
        let trainer = Trainer::new(SolverKind::Smo)
            .kernel(Kernel::Linear)
            .shrinking(false);
        bench.run(&format!("paper-no-shrink/m={m}"), || {
            let report = trainer.fit(&ds.x).expect("train");
            objectives.push(report.stats.objective);
            vec![
                ("iterations".into(), report.stats.iterations as f64),
                ("objective".into(), report.stats.objective),
            ]
        });
        // all heuristics must land on the same optimum
        let lo = objectives.iter().cloned().fold(f64::MAX, f64::min);
        let hi = objectives.iter().cloned().fold(f64::MIN, f64::max);
        assert!(
            hi - lo < 1e-3 * hi.abs().max(1e-9),
            "objectives diverge at m={m}: [{lo}, {hi}]"
        );
    }
    bench.report("A1 — working-set heuristic ablation (same optimum, different effort)");
}
