//! Bench: **S1** — coordinator throughput/latency under open-loop load.
//!
//! Measures the dynamic batcher end to end: many single-query requests
//! fired concurrently against the coordinator, with the batcher on
//! (size/deadline flush) vs effectively off (max_batch = 1). Reports
//! req/s, mean batch size, and latency quantiles — the batching-
//! amortization story the L3 serving layer exists for.
//!
//! Run: `cargo bench --bench serving`

use slabsvm::bench::Bench;
use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::{SolverKind, Trainer};

fn main() {
    let mut bench = Bench::from_env();
    let n_requests = 4000usize;
    let eval = SlabConfig::default().generate_eval(n_requests, n_requests, 17);
    let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);

    let mut engines = vec![("native", Engine::Native)];
    match Engine::pjrt("artifacts") {
        Ok(e) => engines.push(("pjrt", e)),
        Err(e) => eprintln!("pjrt engine unavailable ({e}); native only"),
    }

    for (ename, engine) in engines {
        for (label, cfg) in [
            (
                "batched",
                BatcherConfig { max_batch: 256, max_wait_us: 500, queue_cap: 65536 },
            ),
            (
                "unbatched",
                BatcherConfig { max_batch: 1, max_wait_us: 1, queue_cap: 65536 },
            ),
        ] {
            let engine = engine.clone();
            bench.run(&format!("serve-{ename}-{label}/n={n_requests}"), || {
                let c = Coordinator::start(engine.clone(), cfg, 2);
                let ds = SlabConfig::default().generate(1000, 42);
                c.train_blocking("m", &ds, &trainer).expect("train");
                // trace the scoring path: the batcher records a
                // ScoreQueue span per request (enqueue → batch start)
                // and a Score span per executed batch; their means
                // decompose the latency quantiles below into wait vs
                // engine time on the BENCHJSON row
                slabsvm::obs::set_enabled(true);
                let span_floor = slabsvm::obs::now_us();
                let t0 = std::time::Instant::now();
                let rxs: Vec<_> = (0..n_requests)
                    .map(|i| c.score_async("m", vec![eval.x.row(i).to_vec()]))
                    .collect();
                let mut ok = 0usize;
                for rx in rxs {
                    if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
                        ok += 1;
                    }
                }
                let dt = t0.elapsed().as_secs_f64();
                let spans = slabsvm::obs::recent_spans(usize::MAX);
                slabsvm::obs::set_enabled(false);
                let (mut q_sum, mut q_n, mut s_sum, mut s_n) =
                    (0u64, 0u64, 0u64, 0u64);
                for s in spans.iter().filter(|s| s.start_us >= span_floor) {
                    match s.stage {
                        slabsvm::obs::Stage::ScoreQueue => {
                            q_sum += s.dur_us;
                            q_n += 1;
                        }
                        slabsvm::obs::Stage::Score => {
                            s_sum += s.dur_us;
                            s_n += 1;
                        }
                        _ => {}
                    }
                }
                let stats = c.stats();
                let out = vec![
                    ("req_per_s".into(), ok as f64 / dt),
                    ("mean_batch".into(), stats.mean_batch_size()),
                    ("p50_us".into(), stats.request_latency.quantile_us(0.5) as f64),
                    ("p99_us".into(), stats.request_latency.quantile_us(0.99) as f64),
                    ("queue_us".into(), q_sum as f64 / q_n.max(1) as f64),
                    ("score_us".into(), s_sum as f64 / s_n.max(1) as f64),
                    ("errors".into(), stats.errors.get() as f64),
                ];
                c.shutdown();
                out
            });
        }
    }
    bench.report("S1 — coordinator open-loop serving (engine x batching)");
}
