//! Bench: **A2** — kernel-row cache policy ablation (paper ref [37]).
//!
//! The paper's related work motivates kernel-value caching (LFU, Li/
//! Wen/He 2019) as a lever on SVM training time. This bench sweeps the
//! row-cache policy (LRU vs LFU) and capacity against the full-Gram
//! precompute, reporting train time and cache hit rate. Expected shape:
//! precompute wins at paper scale (memory is cheap at m ≤ 5000), caches
//! approach it as capacity grows, LFU ≥ LRU at small capacities because
//! SMO's working set is heavy-tailed (hot violators are re-selected).
//!
//! Run: `cargo bench --bench ablation_cache`

use slabsvm::bench::Bench;
use slabsvm::cache::{CachedRows, Policy};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::smo::{train_cached, train_full, SmoParams};

fn main() {
    let mut bench = Bench::from_env();
    let params = SmoParams::default();

    for &m in &[1000usize, 2000] {
        let ds = SlabConfig::default().generate(m, 5000 + m as u64);

        bench.run(&format!("precomputed/m={m}"), || {
            let (_, out) = train_full(&ds.x, Kernel::Linear, &params).expect("train");
            vec![("iterations".into(), out.stats.iterations as f64)]
        });

        for policy in [Policy::Lru, Policy::Lfu] {
            for frac in [0.05f64, 0.25, 1.0] {
                let cap = ((m as f64 * frac) as usize).max(2);
                let name = format!(
                    "{}{:.0}%/m={m}",
                    if policy == Policy::Lru { "lru-" } else { "lfu-" },
                    frac * 100.0
                );
                bench.run(&name, || {
                    let cache =
                        CachedRows::with_policy(&ds.x, Kernel::Linear, cap, policy);
                    let (_, out) = train_cached(&ds.x, Kernel::Linear, &params, cache)
                        .expect("train");
                    vec![
                        ("hit_rate".into(), out.stats.cache.hit_rate()),
                        ("evictions".into(), out.stats.cache.evictions as f64),
                        ("iterations".into(), out.stats.iterations as f64),
                    ]
                });
            }
        }
    }
    bench.report("A2 — kernel cache policy x capacity (train seconds, hit rate)");
}
