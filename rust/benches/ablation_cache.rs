//! Bench: **A2** — kernel-row cache policy ablation (paper ref [37]).
//!
//! The paper's related work motivates kernel-value caching (LFU, Li/
//! Wen/He 2019) as a lever on SVM training time. This bench sweeps the
//! row-cache policy (LRU vs LFU) and capacity against the full-Gram
//! precompute — in the unified API the cache is just the
//! `Trainer::cache_rows(capacity, policy)` layer. Reports train time and
//! cache hit rate. Expected shape: precompute wins at paper scale
//! (memory is cheap at m ≤ 5000), caches approach it as capacity grows,
//! LFU ≥ LRU at small capacities because SMO's working set is
//! heavy-tailed (hot violators are re-selected).
//!
//! Run: `cargo bench --bench ablation_cache`

use slabsvm::bench::Bench;
use slabsvm::cache::Policy;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{SolverKind, Trainer};

fn main() {
    let mut bench = Bench::from_env();
    let base = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);

    for &m in &[1000usize, 2000] {
        let ds = SlabConfig::default().generate(m, 5000 + m as u64);

        bench.run(&format!("precomputed/m={m}"), || {
            let report = base.fit(&ds.x).expect("train");
            vec![("iterations".into(), report.stats.iterations as f64)]
        });

        for policy in [Policy::Lru, Policy::Lfu] {
            for frac in [0.05f64, 0.25, 1.0] {
                let cap = ((m as f64 * frac) as usize).max(2);
                let name = format!(
                    "{}{:.0}%/m={m}",
                    if policy == Policy::Lru { "lru-" } else { "lfu-" },
                    frac * 100.0
                );
                let trainer = base.clone().cache_rows(cap, policy);
                bench.run(&name, || {
                    let report = trainer.fit(&ds.x).expect("train");
                    vec![
                        ("hit_rate".into(), report.stats.cache.hit_rate()),
                        ("evictions".into(), report.stats.cache.evictions as f64),
                        ("iterations".into(), report.stats.iterations as f64),
                    ]
                });
            }
        }
    }
    bench.report("A2 — kernel cache policy x capacity (train seconds, hit rate)");
}
