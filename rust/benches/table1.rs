//! Bench: **Table 1** — SMO training time and MCC vs dataset size.
//!
//! Regenerates the paper's only table: training time and Matthews
//! Correlation Coefficient for m ∈ {500, 1000, 2000, 5000} with the
//! linear kernel and the paper's constants ν₁ = 0.5, ν₂ = 0.01, ε = 2/3.
//! MCC is measured on a labeled eval set (m/2 positives + m/2 anomalies)
//! — the paper never states its eval protocol, see DESIGN.md
//! §Substitutions. Absolute seconds differ from the paper's 2020-era
//! hardware; the claim under test is the growth *shape*.
//!
//! Run: `cargo bench --bench table1`  (SLABSVM_BENCH_FAST=1 for smoke)

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{SolverKind, Trainer};

const PAPER: &[(usize, f64, f64)] = &[
    (500, 0.35, 0.07),
    (1000, 0.67, 0.13),
    (2000, 2.1, 0.26),
    (5000, 5.91, 0.33),
];

fn main() {
    let mut bench = Bench::from_env();
    // the paper's constants are the Trainer defaults
    let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);

    for &(m, paper_t, paper_mcc) in PAPER {
        let ds = SlabConfig::default().generate(m, 1000 + m as u64);
        let eval = SlabConfig::default().generate_eval(m / 2, m / 2, 77 + m as u64);
        bench.run(&format!("table1/m={m}"), || {
            let report = trainer.fit(&ds.x).expect("train");
            let mcc = report.model.evaluate(&eval).mcc();
            vec![
                ("mcc".into(), mcc),
                ("iterations".into(), report.stats.iterations as f64),
                ("n_sv".into(), report.model.n_sv() as f64),
                ("paper_time_s".into(), paper_t),
                ("paper_mcc".into(), paper_mcc),
            ]
        });
    }
    bench.report("Table 1 — SMO train time + MCC vs m (linear kernel, paper constants)");

    // growth-shape summary: time ratios between consecutive sizes
    let r = bench.results();
    println!("\ngrowth shape (ours vs paper time ratios):");
    for (i, w) in r.windows(2).enumerate() {
        let ours = w[1].median() / w[0].median().max(1e-12);
        let paper = PAPER[i + 1].1 / PAPER[i].1;
        println!(
            "  {} -> {}: ours x{:.2}, paper x{:.2}",
            w[0].name, w[1].name, ours, paper
        );
    }
}
