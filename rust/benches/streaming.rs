//! Bench: **ST1** — incremental stream update vs full retrain per sample.
//!
//! The streaming subsystem's reason to exist, quantified: once the
//! window is full, absorbing one sample via [`IncrementalSmo::push`]
//! (rank-1 Gram maintenance + mass-conserving perturbation + a few
//! warm-started repair sweeps) must be far cheaper than what a naive
//! serving loop pays — a cold [`Trainer::fit`] on the whole window for
//! every arriving sample (full Gram build + cold SMO solve).
//!
//! Reported per window size (and in the BENCHJSON line): median seconds
//! per incremental update (`update_s`), median seconds per full retrain
//! (`retrain_s`), and the ratio (`speedup` — the acceptance floor is
//! 10× at window 2000).
//!
//! Run: `cargo bench --bench streaming`

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::kernel::Kernel;
use slabsvm::linalg::median;
use slabsvm::solver::{SolverKind, Trainer};
use slabsvm::stream::{IncrementalConfig, IncrementalSmo};

fn main() {
    let fast = std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1");
    let mut bench = if fast {
        Bench::new(0, 1, 60.0)
    } else {
        Bench::new(0, 2, 300.0)
    };
    let windows: &[usize] = if fast { &[200] } else { &[500, 2000] };
    let updates = if fast { 20 } else { 100 };
    let retrains = if fast { 1 } else { 3 };

    for &w in windows {
        bench.run(&format!("stream-update-vs-retrain/w={w}"), || {
            let mut stream = SlabStream::new(SlabConfig::default(), 1234);
            let mut inc = IncrementalSmo::new(
                Kernel::Linear,
                w,
                2,
                IncrementalConfig::default(),
            );
            // fill to steady state (growth is the uninteresting phase)
            for _ in 0..w {
                inc.push(&stream.next_point()).expect("fill");
            }

            // incremental path: absorb one sample, window full
            let mut update_times = Vec::with_capacity(updates);
            for _ in 0..updates {
                let x = stream.next_point();
                let t0 = std::time::Instant::now();
                inc.push(&x).expect("incremental update");
                update_times.push(t0.elapsed().as_secs_f64());
            }
            let update_s = median(&update_times);

            // baseline: what retrain-per-sample serving would pay for the
            // same freshness — a cold fit on the current window contents
            let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
            let mut retrain_times = Vec::with_capacity(retrains);
            for _ in 0..retrains {
                inc.push(&stream.next_point()).expect("advance window");
                let snapshot = inc.window().matrix();
                let t0 = std::time::Instant::now();
                let report = trainer.fit(&snapshot).expect("full retrain");
                retrain_times.push(t0.elapsed().as_secs_f64());
                assert!(report.model.width() > 0.0);
            }
            let retrain_s = median(&retrain_times);

            vec![
                ("update_s".into(), update_s),
                ("updates_per_s".into(), 1.0 / update_s.max(1e-12)),
                ("retrain_s".into(), retrain_s),
                ("speedup".into(), retrain_s / update_s.max(1e-12)),
                (
                    "repair_iters_total".into(),
                    inc.repair_iterations() as f64,
                ),
            ]
        });
    }
    bench.report("ST1 — incremental stream update vs full retrain per sample");
}
