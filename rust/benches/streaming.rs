//! Benches: **ST1** — incremental stream update vs full retrain per
//! sample — and **MS1** — aggregate absorb throughput of the sharded
//! multi-stream session manager vs sequential single-stream loops.
//!
//! ST1, the streaming subsystem's reason to exist, quantified: once the
//! window is full, absorbing one sample via [`IncrementalSmo::push`]
//! (rank-1 Gram maintenance + mass-conserving perturbation + a few
//! warm-started repair sweeps) must be far cheaper than what a naive
//! serving loop pays — a cold [`Trainer::fit`] on the whole window for
//! every arriving sample (full Gram build + cold SMO solve). Reported
//! per window size (and in the BENCHJSON line): median seconds per
//! incremental update (`update_s`), median seconds per full retrain
//! (`retrain_s`), and the ratio (`speedup` — the acceptance floor is
//! 10× at window 2000).
//!
//! MS1, the manager's reason to exist, quantified: the same per-stream
//! absorb work fanned across shard worker threads must beat running the
//! M streams one after another on the caller thread. Reported per
//! stream count M ∈ {1, 4, 16}: wall seconds and aggregate updates/s
//! for both paths plus the ratio (`speedup` — the acceptance floor is
//! 2× at M = 16 on ≥ 2 shard workers). Before timing is trusted, every
//! stream's final objective and (ρ1, ρ2) are asserted to match the
//! single-stream path within 1e-9 — the manager must parallelize the
//! work, not change it.
//!
//! **PS1** — restore-resume vs cold window refill — quantifies the
//! persistence subsystem: a restored session (decode + Gram rebuild +
//! certificate, `restore_s`) serves at the reference AUC immediately
//! (`restore_samples_to_auc` = 0), while a cold session must absorb
//! `cold_samples_to_auc` fresh samples over `cold_refill_s` seconds
//! before its published model recovers the reference AUC (within
//! 0.02). ρ parity ≤ 1e-9 between the live and restored session is
//! asserted before timing is trusted.
//!
//! Run: `cargo bench --bench streaming`

use slabsvm::bench::Bench;
use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::kernel::Kernel;
use slabsvm::linalg::median;
use slabsvm::runtime::Engine;
use slabsvm::solver::{SolverKind, Trainer};
use slabsvm::stream::{
    IncrementalConfig, IncrementalSmo, PolicyKind, StreamConfig,
    StreamPoolConfig, StreamSession, StreamSpec,
};

fn main() {
    let fast = std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1");
    let mut bench = if fast {
        Bench::new(0, 1, 60.0)
    } else {
        Bench::new(0, 2, 300.0)
    };
    let windows: &[usize] = if fast { &[200] } else { &[500, 2000] };
    let updates = if fast { 20 } else { 100 };
    let retrains = if fast { 1 } else { 3 };

    for &w in windows {
        bench.run(&format!("stream-update-vs-retrain/w={w}"), || {
            let mut stream = SlabStream::new(SlabConfig::default(), 1234);
            let mut inc = IncrementalSmo::new(
                Kernel::Linear,
                w,
                2,
                IncrementalConfig::default(),
            );
            // fill to steady state (growth is the uninteresting phase)
            for _ in 0..w {
                inc.push(&stream.next_point()).expect("fill");
            }

            // incremental path: absorb one sample, window full. The
            // solver's own stage split (admit/Gram maintenance vs the
            // warm-started repair sweep) rides along so the BENCHJSON
            // trajectory shows WHERE an update regression lives, not
            // just that one happened.
            let mut update_times = Vec::with_capacity(updates);
            let (mut gram_us, mut repair_us, mut iters) = (0u64, 0u64, 0u64);
            for _ in 0..updates {
                let x = stream.next_point();
                let t0 = std::time::Instant::now();
                inc.push(&x).expect("incremental update");
                update_times.push(t0.elapsed().as_secs_f64());
                let (admit, repair) = inc.last_stage_us();
                gram_us += admit;
                repair_us += repair;
                iters += inc.last_stats().iterations as u64;
            }
            let update_s = median(&update_times);

            // baseline: what retrain-per-sample serving would pay for the
            // same freshness — a cold fit on the current window contents
            let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
            let mut retrain_times = Vec::with_capacity(retrains);
            for _ in 0..retrains {
                inc.push(&stream.next_point()).expect("advance window");
                let snapshot = inc.window().matrix();
                let t0 = std::time::Instant::now();
                let report = trainer.fit(&snapshot).expect("full retrain");
                retrain_times.push(t0.elapsed().as_secs_f64());
                assert!(report.model.width() > 0.0);
            }
            let retrain_s = median(&retrain_times);

            // blocked row-kernel throughput on the resident window: the
            // per-absorb Gram maintenance is one such row build, so this
            // is the hot-path kernel rate the absorb cost sits on
            let xmat = inc.window().matrix();
            let probe = stream.next_point();
            let mut krow = vec![0.0; xmat.rows()];
            let krows = 16;
            let tk = std::time::Instant::now();
            for _ in 0..krows {
                Kernel::Linear.row(&xmat, &probe, &mut krow);
                std::hint::black_box(&krow);
            }
            let kernel_rows_per_s =
                krows as f64 / tk.elapsed().as_secs_f64().max(1e-12);

            vec![
                ("update_s".into(), update_s),
                ("ns_per_absorb".into(), update_s * 1e9),
                ("kernel_rows_per_s".into(), kernel_rows_per_s),
                ("updates_per_s".into(), 1.0 / update_s.max(1e-12)),
                ("retrain_s".into(), retrain_s),
                ("speedup".into(), retrain_s / update_s.max(1e-12)),
                ("gram_us".into(), gram_us as f64 / updates as f64),
                ("repair_us".into(), repair_us as f64 / updates as f64),
                (
                    "iters_per_absorb".into(),
                    iters as f64 / updates as f64,
                ),
                (
                    "repair_iters_total".into(),
                    inc.repair_iterations() as f64,
                ),
            ]
        });
    }
    // ------------------------------------------------------------- MS1
    let stream_counts: &[usize] = if fast { &[1, 4] } else { &[1, 4, 16] };
    let (ms_window, ms_updates) = if fast { (48, 48) } else { (128, 128) };
    // the MS1 claim is about ≥ 2 shard workers
    let shard_workers =
        slabsvm::util::threadpool::default_threads().clamp(2, 4);

    for &m_streams in stream_counts {
        bench.run(&format!("multi-stream-absorb/m={m_streams}"), || {
            let per_stream = ms_window + ms_updates;
            // pinned per-stream sample sequences (identical for both paths)
            let seqs: Vec<Vec<[f64; 2]>> = (0..m_streams)
                .map(|i| {
                    let mut s = SlabStream::new(
                        SlabConfig::default(),
                        7000 + i as u64,
                    );
                    (0..per_stream).map(|_| s.next_point()).collect()
                })
                .collect();
            let cfg = StreamConfig {
                kernel: Kernel::Linear,
                dim: 2,
                window: ms_window,
                min_train: ms_window / 2,
                ..Default::default()
            };

            // baseline: the M streams absorbed one after another on this
            // thread — exactly what a single-writer coordinator pays
            let t0 = std::time::Instant::now();
            let baseline: Vec<(f64, (f64, f64))> = seqs
                .iter()
                .map(|seq| {
                    let mut session = StreamSession::new("seq", cfg);
                    for x in seq {
                        session.absorb(x).expect("sequential absorb");
                    }
                    (
                        session.solver().report().stats.objective,
                        session.solver().rho(),
                    )
                })
                .collect();
            let seq_s = t0.elapsed().as_secs_f64();

            // manager path: M producers, sessions sharded across workers
            let c = Coordinator::start_with_streams(
                Engine::Native,
                BatcherConfig::default(),
                1,
                StreamPoolConfig {
                    shards: shard_workers,
                    mailbox_cap: 256,
                    checkpoint: None,
                },
            );
            c.open_streams(
                (0..m_streams)
                    .map(|i| StreamSpec::new(format!("t{i}"), cfg))
                    .collect(),
            )
            .expect("open streams");
            // trace the managed path: every push mints a trace id and
            // the shard workers record Queue/Gram/Repair/Publish spans;
            // their per-stage means ride the BENCHJSON row (the span
            // ring keeps the most recent 8192, i.e. the steady-state
            // tail of large runs — exactly the regime MS1 is about)
            slabsvm::obs::set_enabled(true);
            let span_floor = slabsvm::obs::now_us();
            let t1 = std::time::Instant::now();
            std::thread::scope(|scope| {
                for (i, seq) in seqs.iter().enumerate() {
                    let c = &c;
                    scope.spawn(move || {
                        let name = format!("t{i}");
                        for x in seq {
                            c.push(&name, x).expect("managed push");
                        }
                    });
                }
            });
            c.quiesce_streams();
            let mgr_s = t1.elapsed().as_secs_f64();
            let spans = slabsvm::obs::recent_spans(usize::MAX);
            slabsvm::obs::set_enabled(false);
            // stage means: [queue, gram, repair, publish]
            let (mut sums, mut counts) = ([0u64; 4], [0u64; 4]);
            let (mut abs_iters, mut absorbs) = (0u64, 0u64);
            for s in spans.iter().filter(|s| s.start_us >= span_floor) {
                let slot = match s.stage {
                    slabsvm::obs::Stage::Queue => 0,
                    slabsvm::obs::Stage::Gram => 1,
                    slabsvm::obs::Stage::Repair => 2,
                    slabsvm::obs::Stage::Publish => 3,
                    slabsvm::obs::Stage::Absorb => {
                        abs_iters += s.iters;
                        absorbs += 1;
                        continue;
                    }
                    _ => continue,
                };
                sums[slot] += s.dur_us;
                counts[slot] += 1;
            }
            let mean = |i: usize| sums[i] as f64 / counts[i].max(1) as f64;

            // parity gate: a fast wrong manager is worthless
            for (i, &(obj, rho)) in baseline.iter().enumerate() {
                let s = c.close_stream(&format!("t{i}")).expect("close");
                assert_eq!(s.updates as usize, per_stream);
                assert!(
                    (s.objective - obj).abs() <= 1e-9 * obj.abs().max(1.0),
                    "stream {i} objective diverged: {} vs {obj}",
                    s.objective
                );
                assert!(
                    (s.rho.0 - rho.0).abs() <= 1e-9
                        && (s.rho.1 - rho.1).abs() <= 1e-9,
                    "stream {i} rho diverged: {:?} vs {rho:?}",
                    s.rho
                );
            }
            c.shutdown();

            let total = (m_streams * per_stream) as f64;
            vec![
                ("streams".into(), m_streams as f64),
                ("shards".into(), shard_workers as f64),
                ("seq_s".into(), seq_s),
                ("mgr_s".into(), mgr_s),
                ("seq_updates_per_s".into(), total / seq_s.max(1e-12)),
                ("mgr_updates_per_s".into(), total / mgr_s.max(1e-12)),
                ("ns_per_absorb".into(), mgr_s * 1e9 / total),
                ("speedup".into(), seq_s / mgr_s.max(1e-12)),
                ("queue_us".into(), mean(0)),
                ("gram_us".into(), mean(1)),
                ("repair_us".into(), mean(2)),
                ("publish_us".into(), mean(3)),
                (
                    "iters_per_absorb".into(),
                    abs_iters as f64 / absorbs.max(1) as f64,
                ),
            ]
        });
    }

    // ------------------------------------------------------------- PS1
    let ps_window = if fast { 64 } else { 256 };
    let warm_feed = ps_window + ps_window / 2;
    bench.run(&format!("restore-vs-cold-refill/w={ps_window}"), || {
        let cfg = StreamConfig {
            kernel: Kernel::Linear,
            dim: 2,
            window: ps_window,
            min_train: ps_window / 2,
            ..Default::default()
        };
        let mut stream = SlabStream::new(SlabConfig::default(), 4242);
        let mut live = StreamSession::new("ps1", cfg);
        for _ in 0..warm_feed {
            live.absorb(&stream.next_point()).expect("warm feed");
        }
        let eval = SlabConfig::default().generate_eval(200, 200, 4243);
        let auc_of = |model: &slabsvm::solver::ocssvm::SlabModel| {
            let margins: Vec<f64> = (0..eval.len())
                .map(|i| model.margin(eval.x.row(i)))
                .collect();
            slabsvm::metrics::roc_auc(&eval.y, &margins)
        };
        let ref_auc = auc_of(&live.solver().model());
        let bytes = live.snapshot();

        // restore path: one decode + Gram rebuild + certificate buys
        // back the full model — zero samples to recover AUC
        let t0 = std::time::Instant::now();
        let restored = StreamSession::restore(&bytes).expect("restore");
        let restore_s = t0.elapsed().as_secs_f64();
        let restored_auc = auc_of(&restored.solver().model());
        // parity gate: a fast wrong restore is worthless
        let (l, r) = (live.solver().rho(), restored.solver().rho());
        assert!(
            (l.0 - r.0).abs() <= 1e-9 && (l.1 - r.1).abs() <= 1e-9,
            "restored rho diverged: {l:?} vs {r:?}"
        );
        assert!(
            (restored_auc - ref_auc).abs() <= 1e-9,
            "restored AUC {restored_auc} != reference {ref_auc}"
        );

        // cold path: a fresh session on the SAME continuing stream must
        // refill before its model recovers the reference AUC
        let cap = 4 * ps_window;
        let mut cold = StreamSession::new("cold", cfg);
        let mut cold_samples = 0usize;
        let mut recovered = None;
        let t1 = std::time::Instant::now();
        while cold_samples < cap {
            let a = cold.absorb(&stream.next_point()).expect("cold absorb");
            cold_samples += 1;
            if let Some(model) = a.model {
                if cold_samples % 4 == 0 && auc_of(&model) >= ref_auc - 0.02
                {
                    recovered = Some(cold_samples);
                    break;
                }
            }
        }
        let cold_s = t1.elapsed().as_secs_f64();
        vec![
            ("ref_auc".into(), ref_auc),
            ("restore_s".into(), restore_s),
            ("restore_samples_to_auc".into(), 0.0),
            (
                "cold_samples_to_auc".into(),
                recovered.unwrap_or(cap) as f64,
            ),
            ("cold_refill_s".into(), cold_s),
            (
                "ns_per_absorb".into(),
                cold_s * 1e9 / (cold_samples as f64).max(1.0),
            ),
            ("refill_speedup".into(), cold_s / restore_s.max(1e-12)),
        ]
    });

    // ------------------------------------------------------------- WP1
    // Eviction policy vs window size: the InteriorFirst policy keeps
    // the support set resident (interior |α−ᾱ| ≈ 0 points leave first),
    // so a smaller window should hold the AUC a larger FIFO window
    // needs. Every run streams the same drifting sequence (mean-shift
    // ramp — the SlabStream generators) and is scored on an eval set
    // drawn from the stream's FINAL configuration, so the number
    // measures how well the surviving window represents the current
    // band. The update cost is timed alongside: InteriorFirst evicts
    // zero-mass points, so its perturbation is smaller where FIFO may
    // rip out a support vector per absorb.
    let wp_windows: &[usize] = if fast { &[24, 48] } else { &[32, 64, 128] };
    let wp_points = if fast { 260 } else { 1200 };
    for policy in PolicyKind::ALL {
        for &w in wp_windows {
            bench.run(&format!("window-policy-auc/{policy}/w={w}"), || {
                let mut cfg = StreamConfig {
                    kernel: Kernel::Linear,
                    dim: 2,
                    window: w,
                    min_train: w / 2,
                    ..Default::default()
                };
                cfg.incremental.policy = policy;
                let mut session = StreamSession::new("wp1", cfg);
                // a mild mean-shift ramp (two noise-spreads): enough
                // that a window full of stale points mis-centers the
                // slab, small enough that the policy comparison is
                // about window composition, not raw tracking speed
                let mut stream =
                    SlabStream::new(SlabConfig::default(), 31415).with_drift(
                        slabsvm::data::synthetic::DriftSchedule {
                            drift: slabsvm::data::synthetic::Drift::MeanShift {
                                delta: -0.5,
                            },
                            start: wp_points / 2,
                            duration: wp_points / 4,
                        },
                    );
                let t0 = std::time::Instant::now();
                for _ in 0..wp_points {
                    session.absorb(&stream.next_point()).expect("wp1 absorb");
                }
                let stream_s = t0.elapsed().as_secs_f64();
                // eval against the post-drift band the stream ended on
                let eval = stream
                    .config_at(wp_points)
                    .generate_eval(250, 250, 2718);
                let model = session.solver().model();
                let margins: Vec<f64> = (0..eval.len())
                    .map(|i| model.margin(eval.x.row(i)))
                    .collect();
                let auc = slabsvm::metrics::roc_auc(&eval.y, &margins);
                // structural sanity only — the AUC itself is the
                // reported measurement, not a gate (a quality gate on a
                // drifting workload would flap; the BENCHJSON trajectory
                // is what the artifact lane archives)
                assert!(
                    (0.0..=1.0).contains(&auc) && model.n_sv() > 0,
                    "policy {policy} w={w}: degenerate run (auc {auc})"
                );
                vec![
                    ("window".into(), w as f64),
                    (
                        "policy_interior_first".into(),
                        (policy == PolicyKind::InteriorFirst) as u8 as f64,
                    ),
                    ("auc".into(), auc),
                    ("n_sv".into(), model.n_sv() as f64),
                    ("stream_s".into(), stream_s),
                    (
                        "updates_per_s".into(),
                        wp_points as f64 / stream_s.max(1e-12),
                    ),
                    (
                        "repair_iters_total".into(),
                        session.solver().repair_iterations() as f64,
                    ),
                ]
            });
        }
    }

    bench.report(
        "ST1 — incremental update vs full retrain per sample; \
         MS1 — sharded multi-stream absorb throughput vs sequential; \
         PS1 — snapshot restore-resume vs cold window refill; \
         WP1 — eviction policy (fifo vs interior-first) AUC vs window size",
    );
}
