//! Bench: **SV1** — the HTTP front door under concurrent tenant
//! connections.
//!
//! Spins up the full serving stack in-process (coordinator + router +
//! `std::net` listener on loopback), then drives it with K concurrent
//! keep-alive TCP connections — K = 10³ in the full run, scaled down
//! under `SLABSVM_BENCH_FAST=1` — each alternating scoring requests
//! and stream pushes for its tenant. Reports wall-clock RPS plus the
//! server-side request-latency quantiles (`slabsvm_serve_latency_us`,
//! parse → response written) and the shed/stale admission counters, so
//! the perf floor in CI tracks the whole parse→route→respond path.
//!
//! Run: `cargo bench --bench serve`

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use slabsvm::bench::Bench;
use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::serve::{Router, RouterConfig, ServerConfig};
use slabsvm::solver::{SolverKind, Trainer};
use slabsvm::stream::{StreamConfig, StreamPoolConfig, StreamSpec};

/// Read one HTTP response (head + content-length body); returns status.
fn read_response(conn: &mut TcpStream, scratch: &mut Vec<u8>) -> u16 {
    scratch.clear();
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(head_end) =
            scratch.windows(4).position(|w| w == b"\r\n\r\n")
        {
            let head = String::from_utf8_lossy(&scratch[..head_end]);
            let clen: usize = head
                .lines()
                .find_map(|l| {
                    l.to_ascii_lowercase()
                        .strip_prefix("content-length:")
                        .map(|v| v.trim().parse().expect("content-length"))
                })
                .unwrap_or(0);
            if scratch.len() >= head_end + 4 + clen {
                return head
                    .split(' ')
                    .nth(1)
                    .and_then(|s| s.parse().ok())
                    .expect("status line");
            }
        }
        let n = conn.read(&mut tmp).expect("read response");
        assert!(n > 0, "server closed mid-response");
        scratch.extend_from_slice(&tmp[..n]);
    }
}

/// One client connection's workload: alternate score and push.
fn client(
    addr: SocketAddr,
    stream_name: String,
    requests: usize,
) -> (usize, usize) {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_nodelay(true).expect("nodelay");
    let mut scratch = Vec::new();
    let (mut ok, mut shed) = (0usize, 0usize);
    for i in 0..requests {
        let (path, body) = if i % 2 == 0 {
            (
                "/v1/score/demo".to_string(),
                "{\"queries\": [[0.5, 0.5]]}".to_string(),
            )
        } else {
            (
                format!("/v1/streams/{stream_name}/push"),
                "{\"x\": [0.1, 0.2]}".to_string(),
            )
        };
        let req = format!(
            "POST {path} HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        );
        conn.write_all(req.as_bytes()).expect("write request");
        match read_response(&mut conn, &mut scratch) {
            s if s < 300 => ok += 1,
            429 | 503 => shed += 1,
            _ => {}
        }
    }
    (ok, shed)
}

fn main() {
    let mut bench = Bench::from_env();
    let fast = std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1");
    // SV1's headline point: 10³ concurrent tenant connections
    let conns = if fast { 64 } else { 1000 };
    let reqs_per_conn = if fast { 6 } else { 20 };
    let n_streams = if fast { 4 } else { 16 };

    bench.run(&format!("serve-tcp/conns={conns}"), || {
        let coord = Arc::new(Coordinator::start_with_streams(
            Engine::Native,
            BatcherConfig {
                max_batch: 256,
                max_wait_us: 500,
                queue_cap: 65536,
            },
            2,
            StreamPoolConfig {
                shards: 4,
                mailbox_cap: 4096,
                checkpoint: None,
            },
        ));
        let ds = SlabConfig::default().generate(512, 42);
        let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
        coord.train_blocking("demo", &ds, &trainer).expect("train demo");
        let specs: Vec<StreamSpec> = (0..n_streams)
            .map(|i| {
                StreamSpec::new(
                    format!("t{i}"),
                    StreamConfig {
                        kernel: Kernel::Linear,
                        dim: 2,
                        window: 256,
                        min_train: 64,
                        ..Default::default()
                    },
                )
            })
            .collect();
        coord.open_streams(specs).expect("open streams");

        let router =
            Arc::new(Router::new(Arc::clone(&coord), RouterConfig::default()));
        let server = slabsvm::serve::start(
            Arc::clone(&router),
            ServerConfig {
                max_conns: conns + 16,
                ..ServerConfig::default()
            },
        )
        .expect("bind loopback");
        let addr = server.addr();

        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..conns)
            .map(|i| {
                let name = format!("t{}", i % n_streams);
                std::thread::spawn(move || client(addr, name, reqs_per_conn))
            })
            .collect();
        let (mut ok, mut shed_client) = (0usize, 0usize);
        for h in handles {
            let (o, s) = h.join().expect("client thread");
            ok += o;
            shed_client += s;
        }
        let dt = t0.elapsed().as_secs_f64();

        let stats = coord.stats();
        let out = vec![
            ("rps".into(), (conns * reqs_per_conn) as f64 / dt),
            ("ok".into(), ok as f64),
            ("p50_us".into(), stats.serve_latency.quantile_us(0.5) as f64),
            ("p99_us".into(), stats.serve_latency.quantile_us(0.99) as f64),
            ("shed".into(), (stats.serve_shed.get().max(shed_client as u64)) as f64),
            ("stale".into(), stats.serve_stale_served.get() as f64),
        ];
        drop(server);
        coord.quiesce_streams();
        out
    });

    bench.report("SV1 — HTTP front door under concurrent tenant connections");
}
