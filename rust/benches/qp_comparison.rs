//! Bench: **T1-ext** — SMO vs generic QP solvers (the scaling claim).
//!
//! The paper's abstract claims SMO "scales better to large sets of
//! training data than other QP solvers". This bench regenerates that
//! comparison on the identical dual problem: the paper's SMO vs a
//! projected-gradient (FISTA) first-order solver vs a primal-dual
//! interior-point method (each iteration of which factorizes a dense
//! 2m×2m matrix — the O(m³) cost generic QP brings).
//!
//! Expected shape: IPM slowest and growing ~cubically (capped at
//! m ≤ 1000 to keep runtime sane), PG in between (O(m²) per iteration,
//! many iterations), SMO fastest with gentle growth. Each solver's
//! solution is certified against the SMO objective before timing.
//!
//! Run: `cargo bench --bench qp_comparison`

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{qp_ipm, qp_pg, smo};

fn main() {
    let mut bench = Bench::from_env();
    let sizes = [250usize, 500, 1000, 2000];

    // correctness gate: all three reach the same objective at m=250
    {
        let ds = SlabConfig::default().generate(250, 31);
        let k = Kernel::Linear.gram(&ds.x, 8);
        let (_, smo_out) =
            smo::train_full(&ds.x, Kernel::Linear, &smo::SmoParams::default())
                .expect("smo");
        let (_, _, _, _, pg) = qp_pg::solve(&k, &qp_pg::PgParams::default()).expect("pg");
        let (_, _, _, _, ipm) =
            qp_ipm::solve(&k, &qp_ipm::IpmParams::default()).expect("ipm");
        let obj = smo_out.stats.objective;
        assert!(
            (pg.objective - obj).abs() < 1e-2 * obj.abs().max(1e-9),
            "PG objective {} vs SMO {}",
            pg.objective,
            obj
        );
        assert!(
            (ipm.objective - obj).abs() < 1e-2 * obj.abs().max(1e-9),
            "IPM objective {} vs SMO {}",
            ipm.objective,
            obj
        );
        println!("objective agreement at m=250: smo={obj:.4} pg={:.4} ipm={:.4}",
                 pg.objective, ipm.objective);
    }

    for &m in &sizes {
        let ds = SlabConfig::default().generate(m, 3000 + m as u64);

        bench.run(&format!("smo/m={m}"), || {
            let (_, out) =
                smo::train_full(&ds.x, Kernel::Linear, &smo::SmoParams::default())
                    .expect("smo");
            vec![("iterations".into(), out.stats.iterations as f64)]
        });

        bench.run(&format!("proj-grad/m={m}"), || {
            let (_, st) =
                qp_pg::train(&ds.x, Kernel::Linear, &qp_pg::PgParams::default())
                    .expect("pg");
            vec![("iterations".into(), st.iterations as f64)]
        });

        if m <= 1000 {
            bench.run(&format!("ipm/m={m}"), || {
                let (_, st) = qp_ipm::train(
                    &ds.x,
                    Kernel::Linear,
                    &qp_ipm::IpmParams::default(),
                )
                .expect("ipm");
                vec![("iterations".into(), st.iterations as f64)]
            });
        }
    }
    bench.report("T1-ext — SMO vs projected-gradient vs interior-point (train seconds)");
    println!("\n(ipm capped at m<=1000: each iteration factorizes a dense 2m x 2m matrix)");
}
