//! Bench: **T1-ext** — SMO vs generic QP solvers (the scaling claim).
//!
//! The paper's abstract claims SMO "scales better to large sets of
//! training data than other QP solvers". This bench regenerates that
//! comparison on the identical dual problem: the paper's SMO vs a
//! projected-gradient (FISTA) first-order solver vs a primal-dual
//! interior-point method (each iteration of which factorizes a dense
//! 2m×2m matrix — the O(m³) cost generic QP brings). All three run
//! through the one `Trainer` API — the bench body is a loop over
//! `SolverKind`, which is exactly the apples-to-apples dispatch the
//! unified interface exists for.
//!
//! Expected shape: IPM slowest and growing ~cubically (capped at
//! m ≤ 1000 to keep runtime sane), PG in between (O(m²) per iteration,
//! many iterations), SMO fastest with gentle growth. Each solver's
//! objective is checked against SMO's before timing.
//!
//! Run: `cargo bench --bench qp_comparison`

use slabsvm::bench::Bench;
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{SolverKind, Trainer};

const KINDS: [SolverKind; 3] = [SolverKind::Smo, SolverKind::Pg, SolverKind::Ipm];

fn main() {
    let mut bench = Bench::from_env();
    let sizes = [250usize, 500, 1000, 2000];

    // correctness gate: all three reach the same objective at m=250
    {
        let ds = SlabConfig::default().generate(250, 31);
        let objectives: Vec<f64> = KINDS
            .iter()
            .map(|&kind| {
                Trainer::new(kind)
                    .kernel(Kernel::Linear)
                    .fit(&ds.x)
                    .unwrap_or_else(|e| panic!("{kind} failed: {e}"))
                    .stats
                    .objective
            })
            .collect();
        let smo_obj = objectives[0];
        for (kind, obj) in KINDS.iter().zip(&objectives) {
            assert!(
                (obj - smo_obj).abs() < 1e-2 * smo_obj.abs().max(1e-9),
                "{kind} objective {obj} vs SMO {smo_obj}"
            );
        }
        println!(
            "objective agreement at m=250: smo={smo_obj:.4} pg={:.4} ipm={:.4}",
            objectives[1], objectives[2]
        );
    }

    for &m in &sizes {
        let ds = SlabConfig::default().generate(m, 3000 + m as u64);
        for kind in KINDS {
            if kind == SolverKind::Ipm && m > 1000 {
                continue;
            }
            let trainer = Trainer::new(kind).kernel(Kernel::Linear);
            bench.run(&format!("{kind}/m={m}"), || {
                let report = trainer
                    .fit(&ds.x)
                    .unwrap_or_else(|e| panic!("{kind} failed: {e}"));
                vec![
                    ("iterations".into(), report.stats.iterations as f64),
                    ("objective".into(), report.stats.objective),
                ]
            });
        }
    }
    bench.report("T1-ext — SMO vs projected-gradient vs interior-point (train seconds)");
    println!("\n(ipm capped at m<=1000: each iteration factorizes a dense 2m x 2m matrix)");
}
