//! Offline stub of the `xla` PJRT bindings.
//!
//! Mirrors exactly the slice of the `xla` crate API that
//! `slabsvm::runtime::pjrt` uses (client, compile, execute, literals),
//! but with no XLA/PJRT shared library behind it: every runtime entry
//! point returns an "unavailable" error. [`PjRtClient::cpu`] failing is
//! the load-bearing behavior — `Engine::pjrt(..)` then errors cleanly at
//! startup and every caller falls back to the native engine, which is
//! what the benches, examples and the CLI already handle.
//!
//! On a machine with a real PJRT plugin, replace this path dependency
//! with the actual `xla` bindings; no `slabsvm` source changes needed.

use std::fmt;

/// Stub error: carries the "runtime unavailable" message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias (matches the real crate's `Result`).
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: built against the offline `xla` stub \
         (no XLA/PJRT shared library in this environment)"
            .to_string(),
    ))
}

/// Host-side tensor value. Constructible (so padding helpers compile and
/// run), but device transfer / execution always reports unavailable.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
}

impl Literal {
    /// Build a rank-1 f32 literal from a host slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec() }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    /// Copy the buffer out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Number of host elements currently stored.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }
}

/// Parsed HLO module (stub: never constructible from a file).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer handle (stub: never produced).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle (stub: never produced).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on the device; one `Vec<PjRtBuffer>` per output.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's failure point:
/// it errors immediately, so nothing downstream ever runs.
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literals_are_constructible() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0]);
        assert_eq!(l.element_count(), 3);
        assert!(l.reshape(&[3, 1]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
