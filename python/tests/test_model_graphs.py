"""L2 graph-level tests: the composed jitted functions (model.py) against
end-to-end references, including the bucket-padding contract the rust
runtime relies on and hyper-parameter re-use of a single lowered graph.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

from .conftest import make_data


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


@pytest.mark.parametrize("kind", [ref.LINEAR, ref.RBF])
def test_kmatrix_graph_matches_ref(rng, kind):
    fn = model.kmatrix_fn(kind)
    x = jnp.asarray(make_data(rng, 256, 8))
    (k,) = fn(x, jnp.asarray([0.4, 0.0, 0.0], jnp.float32))
    want = ref.kernel_matrix(x, kind, 0.4)
    np.testing.assert_allclose(k, want, rtol=3e-5, atol=3e-5)


def test_one_lowering_serves_many_hyperparams(rng):
    """Hyper-parameters are runtime inputs: a single compiled executable
    must produce correct results across a g sweep (no retrace)."""
    fn = model.kmatrix_fn(ref.RBF)
    compiled = jax.jit(fn).lower(spec(128, 2), spec(3)).compile()
    x = jnp.asarray(make_data(rng, 128, 2))
    for g in [0.05, 0.3, 1.0, 2.5]:
        (k,) = compiled(x, jnp.asarray([g, 0.0, 0.0], jnp.float32))
        want = ref.kernel_matrix(x, ref.RBF, g)
        np.testing.assert_allclose(k, want, rtol=1e-4, atol=1e-4)


def test_decision_graph_padding_contract(rng):
    """The rust runtime pads supports with zero rows + gamma=0 and pads
    query chunks with zero rows: scores of real queries must be identical
    and padded-query outputs are simply ignored."""
    m_real, m_bucket, q_real, q_bucket, d = 100, 128, 40, 64, 2
    x = make_data(rng, m_real, d)
    gamma = (rng.normal(size=m_real) * 0.05).astype(np.float32)
    xq = make_data(rng, q_real, d)

    xpad = np.zeros((m_bucket, d), np.float32)
    xpad[:m_real] = x
    gpad = np.zeros(m_bucket, np.float32)
    gpad[:m_real] = gamma
    qpad = np.zeros((q_bucket, d), np.float32)
    qpad[:q_real] = xq

    fn = model.decision_fn(ref.LINEAR)
    p5 = jnp.asarray([0, 0, 0, -0.1, 0.4], jnp.float32)
    s_pad, f_pad = fn(jnp.asarray(xpad), jnp.asarray(gpad), p5, jnp.asarray(qpad))
    s_ref, f_ref = ref.decision_scores(
        jnp.asarray(x), jnp.asarray(gamma), -0.1, 0.4, jnp.asarray(xq),
        ref.LINEAR)
    np.testing.assert_allclose(s_pad[:q_real], s_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(f_pad[:q_real], f_ref)


def test_kkt_graph_padding_contract(rng):
    """Padded Gram rows/cols with gamma=0: viol/fbar of the real prefix
    must match the unpadded reference."""
    m_real, m_bucket = 100, 128
    x = make_data(rng, m_real, 3)
    kmat = np.asarray(ref.kernel_matrix(jnp.asarray(x), ref.RBF, 0.5))
    gamma = rng.uniform(-0.02, 0.04, size=m_real).astype(np.float32)

    kpad = np.zeros((m_bucket, m_bucket), np.float32)
    kpad[:m_real, :m_real] = kmat
    gpad = np.zeros(m_bucket, np.float32)
    gpad[:m_real] = gamma

    fn = model.kkt_fn()
    p5 = jnp.asarray([-0.08, 0.3, -0.02, 0.04, 1e-6], jnp.float32)
    v_pad, f_pad = fn(jnp.asarray(kpad), jnp.asarray(gpad), p5)
    v_ref, f_ref = ref.kkt_sweep(
        jnp.asarray(kmat), jnp.asarray(gamma), -0.08, 0.3, -0.02, 0.04, 1e-6)
    np.testing.assert_allclose(v_pad[:m_real], v_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(f_pad[:m_real], f_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    kind=st.sampled_from([ref.LINEAR, ref.RBF, ref.POLY, ref.SIGMOID]),
    m=st.sampled_from([64, 128]),
    q=st.sampled_from([64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decision_graph_sweep(kind, m, q, seed):
    rng = np.random.default_rng(seed)
    fn = model.decision_fn(kind)
    x = jnp.asarray(rng.normal(size=(m, 2)).astype(np.float32))
    gamma = jnp.asarray((rng.normal(size=m) * 0.05).astype(np.float32))
    xq = jnp.asarray(rng.normal(size=(q, 2)).astype(np.float32))
    p5 = jnp.asarray([0.5, 0.2, 2.0, -0.2, 0.6], jnp.float32)
    s, f = fn(x, gamma, p5, xq)
    sr, fr = ref.decision_scores(x, gamma, -0.2, 0.6, xq, kind, 0.5, 0.2, 2.0)
    np.testing.assert_allclose(s, sr, rtol=1e-3, atol=1e-3)
    s = np.asarray(s)
    safe = (np.abs(s + 0.2) > 1e-3) & (np.abs(s - 0.6) > 1e-3)
    np.testing.assert_array_equal(np.asarray(f)[safe], np.asarray(fr)[safe])
