"""L2/AOT: lowering produces valid, executable HLO text; manifest sanity.

These tests exercise the exact interchange path the rust runtime uses:
HLO text -> parse -> compile on the (python-side) CPU client -> execute,
asserting numerics against the oracle. If this passes, the rust side only
needs the xla crate's equivalent plumbing (covered by cargo tests).
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def compile_hlo_text(text):
    """Parse HLO text and compile on the CPU client (mirrors rust runtime)."""
    client = xc.make_cpu_client()
    # Round-trip through the text parser exactly like
    # HloModuleProto::from_text_file does on the rust side.
    comp = xc._xla.hlo_module_to_xla_computation(  # may not exist; fallback
        text) if hasattr(xc._xla, "hlo_module_to_xla_computation") else None
    if comp is None:
        pytest.skip("no python-side HLO text parser in this jaxlib")
    return client, client.compile(comp)


def test_hlo_text_is_emitted_and_nonempty():
    lowered = jax.jit(model.kmatrix_fn(ref.LINEAR)).lower(spec(256, 2), spec(3))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[256,256]" in text  # output Gram shape appears
    assert len(text) > 1000


def test_hlo_entry_signature_decision():
    lowered = jax.jit(model.decision_fn(ref.RBF)).lower(
        spec(256, 2), spec(256), spec(5), spec(64, 2))
    text = aot.to_hlo_text(lowered)
    assert "f32[256,2]" in text and "f32[64,2]" in text
    # tuple root with two q-length outputs
    assert "(f32[64]" in text


def test_no_python_callbacks_in_hlo():
    """interpret=True must lower to pure HLO — a custom-call would mean the
    artifact cannot run on the rust CPU client."""
    for fam in (ref.LINEAR, ref.RBF):
        lowered = jax.jit(model.kmatrix_fn(fam)).lower(spec(256, 2), spec(3))
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text, f"family {fam} emitted a custom-call"


def test_manifest_matches_files():
    manifest_path = ARTIFACTS / "manifest.json"
    if not manifest_path.exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    manifest = json.loads(manifest_path.read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["artifacts"]) >= 20
    for a in manifest["artifacts"]:
        f = ARTIFACTS / a["file"]
        assert f.exists(), f"manifest lists missing artifact {a['file']}"
        assert f.stat().st_size == a["bytes"]
        assert a["kind"] in ("kmatrix", "decision", "kkt")


def test_manifest_covers_paper_buckets():
    """Table 1 needs m up to 5000 -> the 2048 bucket must exist for the
    chunked path, and the linear family (the paper's kernel) must be there."""
    manifest_path = ARTIFACTS / "manifest.json"
    if not manifest_path.exists():
        pytest.skip("artifacts not built")
    arts = json.loads(manifest_path.read_text())["artifacts"]
    kinds = {(a["kind"], a.get("family"), a.get("m")) for a in arts}
    assert ("kmatrix", "linear", 2048) in kinds
    assert ("kkt", "any", 2048) in kinds
    assert any(k[0] == "decision" and k[1] == "linear" for k in kinds)


def test_lowered_kmatrix_executes_correctly(rng):
    """Execute the *lowered* computation (not the jitted fn) and compare to
    the oracle — catches lowering bugs that tracing hides."""
    m, d = 256, 2
    lowered = jax.jit(model.kmatrix_fn(ref.RBF)).lower(spec(m, d), spec(3))
    compiled = lowered.compile()
    x = rng.normal(size=(m, d)).astype(np.float32)
    p = np.asarray([0.5, 0.0, 0.0], np.float32)
    (got,) = compiled(jnp.asarray(x), jnp.asarray(p))
    want = ref.kernel_matrix(jnp.asarray(x), ref.RBF, 0.5)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_lowered_decision_executes_correctly(rng):
    m, d, q = 256, 2, 64
    lowered = jax.jit(model.decision_fn(ref.LINEAR)).lower(
        spec(m, d), spec(m), spec(5), spec(q, d))
    compiled = lowered.compile()
    x = rng.normal(size=(m, d)).astype(np.float32)
    gamma = (rng.normal(size=m) * 0.02).astype(np.float32)
    xq = rng.normal(size=(q, d)).astype(np.float32)
    p = np.asarray([0, 0, 0, -0.1, 0.4], np.float32)
    s, f = compiled(jnp.asarray(x), jnp.asarray(gamma), jnp.asarray(p),
                    jnp.asarray(xq))
    sr, fr = ref.decision_scores(
        jnp.asarray(x), jnp.asarray(gamma), -0.1, 0.4, jnp.asarray(xq),
        ref.LINEAR)
    np.testing.assert_allclose(s, sr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(f, fr)


def test_lowered_kkt_executes_correctly(rng):
    m = 256
    lowered = jax.jit(model.kkt_fn()).lower(spec(m, m), spec(m), spec(5))
    compiled = lowered.compile()
    x = rng.normal(size=(m, 3)).astype(np.float32)
    kmat = np.asarray(ref.kernel_matrix(jnp.asarray(x), ref.RBF, 0.7))
    gamma = (rng.uniform(-0.02, 0.04, size=m)).astype(np.float32)
    p = np.asarray([-0.08, 0.3, -0.02, 0.04, 1e-6], np.float32)
    v, fb = compiled(jnp.asarray(kmat), jnp.asarray(gamma), jnp.asarray(p))
    vr, fbr = ref.kkt_sweep(jnp.asarray(kmat), jnp.asarray(gamma),
                            -0.08, 0.3, -0.02, 0.04, 1e-6)
    np.testing.assert_allclose(v, vr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(fb, fbr, rtol=1e-4, atol=1e-4)
