"""Shared fixtures/strategies for the L1/L2 test suite."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(12345)


def make_data(rng, m, d, scale=1.0):
    """Well-conditioned random sample matrix."""
    return (rng.normal(size=(m, d)) * scale).astype(np.float32)


def make_gamma(rng, m, lo, hi, sum_to=None):
    """Random dual vector inside the box [lo, hi], optionally on the
    sum-constraint hyperplane (paper eq. (32))."""
    g = rng.uniform(lo, hi, size=m)
    if sum_to is not None:
        # project onto the hyperplane, then re-clip (good enough for tests)
        g = g + (sum_to - g.sum()) / m
        g = np.clip(g, lo, hi)
    return g.astype(np.float32)
