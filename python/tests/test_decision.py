"""L1 correctness: batched slab decision function vs oracle + semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decision, ref

from .conftest import make_data

FAMILIES = [ref.LINEAR, ref.RBF, ref.POLY, ref.SIGMOID]


def p5(g, c, degree, rho1, rho2):
    return jnp.asarray([g, c, degree, rho1, rho2], jnp.float32)


@pytest.mark.parametrize("kind", FAMILIES)
def test_matches_ref(rng, kind):
    m, d, q = 128, 4, 64
    x = jnp.asarray(make_data(rng, m, d))
    xq = jnp.asarray(make_data(rng, q, d))
    gamma = jnp.asarray(rng.normal(size=m).astype(np.float32) * 0.05)
    s, f = decision.decision_scores(x, gamma, p5(0.5, 0.3, 2.0, -0.1, 0.4), xq, kind)
    sr, fr = ref.decision_scores(x, gamma, -0.1, 0.4, xq, kind, 0.5, 0.3, 2.0)
    np.testing.assert_allclose(s, sr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(f, fr)


def test_labels_are_sign_of_slab_test(rng):
    """f = +1 iff rho1 <= s <= rho2 (slab membership, paper eq. (4)/(19))."""
    m, d, q = 128, 2, 64
    x = jnp.asarray(make_data(rng, m, d))
    xq = jnp.asarray(make_data(rng, q, d))
    gamma = jnp.asarray(rng.normal(size=m).astype(np.float32) * 0.05)
    rho1, rho2 = -0.05, 0.25
    s, f = decision.decision_scores(
        x, gamma, p5(1.0, 0.0, 0.0, rho1, rho2), xq, ref.LINEAR)
    s, f = np.asarray(s), np.asarray(f)
    inside = (s >= rho1) & (s <= rho2)
    np.testing.assert_array_equal(f > 0, inside)


def test_padded_support_rows_are_inert(rng):
    """gamma=0 on padded rows -> identical scores (runtime bucket contract)."""
    m, d, q = 100, 3, 64
    x = make_data(rng, m, d)
    gamma = (rng.normal(size=m) * 0.05).astype(np.float32)
    xq = make_data(rng, q, d)

    xpad = np.zeros((128, d), np.float32)
    xpad[:m] = x
    gpad = np.zeros(128, np.float32)
    gpad[:m] = gamma

    s_ref, _ = ref.decision_scores(
        jnp.asarray(x), jnp.asarray(gamma), -0.1, 0.4, jnp.asarray(xq),
        ref.RBF, 0.5)
    s_pad, _ = decision.decision_scores(
        jnp.asarray(xpad), jnp.asarray(gpad),
        p5(0.5, 0, 0, -0.1, 0.4), jnp.asarray(xq), ref.RBF)
    np.testing.assert_allclose(s_pad, s_ref, rtol=3e-4, atol=3e-4)


def test_on_plane_points_are_inside(rng):
    """Score exactly at rho1 or rho2 classifies as +1 (inside)."""
    # Engineer a 1-sample support set with k(x, xq) = <x, xq> giving exact
    # scores rho1 and rho2.
    x = jnp.asarray([[1.0, 0.0]], jnp.float32)
    gamma = jnp.asarray([1.0], jnp.float32)
    xq = jnp.asarray([[0.25, 0.0], [0.75, 0.0], [0.5, 0.0], [1.0, 0.0]],
                     jnp.float32)
    s, f = decision.decision_scores(
        x, gamma, p5(0, 0, 0, 0.25, 0.75), xq, ref.LINEAR, qblock=4)
    np.testing.assert_allclose(np.asarray(s), [0.25, 0.75, 0.5, 1.0], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(f), [1.0, 1.0, 1.0, -1.0])


@settings(max_examples=20, deadline=None)
@given(
    kind=st.sampled_from(FAMILIES),
    m=st.sampled_from([64, 128, 256]),
    q=st.sampled_from([64, 128]),
    d=st.sampled_from([1, 2, 8]),
    g=st.floats(0.05, 1.5),
    rho1=st.floats(-0.5, 0.1),
    width=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_decision_sweep(kind, m, q, d, g, rho1, width, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    xq = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    gamma = jnp.asarray((rng.normal(size=m) * 0.05).astype(np.float32))
    rho2 = rho1 + width
    s, f = decision.decision_scores(
        x, gamma, p5(g, 0.2, 2.0, rho1, rho2), xq, kind)
    sr, fr = ref.decision_scores(x, gamma, rho1, rho2, xq, kind, g, 0.2, 2.0)
    np.testing.assert_allclose(s, sr, rtol=1e-3, atol=1e-3)
    # labels may legitimately differ where s is within tol of a plane;
    # assert equality elsewhere.
    s = np.asarray(s)
    safe = (np.abs(s - rho1) > 1e-3) & (np.abs(s - rho2) > 1e-3)
    np.testing.assert_array_equal(np.asarray(f)[safe], np.asarray(fr)[safe])
