"""L1 correctness: Pallas Gram/cross kernels vs the pure-jnp oracle.

The hypothesis sweep is the core signal: random shapes (within the tiling
constraints), random hyper-parameters, all four kernel families, asserted
allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kmatrix, ref

from .conftest import make_data

FAMILIES = [ref.LINEAR, ref.RBF, ref.POLY, ref.SIGMOID]


def p3(g, c, degree):
    return jnp.asarray([g, c, degree], jnp.float32)


# ---------------------------------------------------------------- fixed cases


@pytest.mark.parametrize("kind", FAMILIES)
def test_gram_matches_ref_basic(rng, kind):
    x = make_data(rng, 256, 8)
    got = kmatrix.kernel_matrix(jnp.asarray(x), p3(0.7, 0.5, 2.0), kind)
    want = ref.kernel_matrix(jnp.asarray(x), kind, 0.7, 0.5, 2.0)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("kind", FAMILIES)
def test_cross_matches_ref_basic(rng, kind):
    x = make_data(rng, 128, 4)
    xq = make_data(rng, 64, 4)
    got = kmatrix.kernel_cross(
        jnp.asarray(x), jnp.asarray(xq), p3(0.3, 1.0, 3.0), kind)
    want = ref.kernel_cross(jnp.asarray(x), jnp.asarray(xq), kind, 0.3, 1.0, 3.0)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_gram_is_symmetric(rng):
    x = make_data(rng, 256, 8)
    k = np.asarray(kmatrix.kernel_matrix(jnp.asarray(x), p3(0.7, 0, 0), ref.RBF))
    np.testing.assert_allclose(k, k.T, rtol=1e-6, atol=1e-6)


def test_rbf_diagonal_is_one(rng):
    x = make_data(rng, 128, 8)
    k = np.asarray(kmatrix.kernel_matrix(jnp.asarray(x), p3(0.9, 0, 0), ref.RBF))
    np.testing.assert_allclose(np.diag(k), np.ones(128), rtol=1e-5)


def test_rbf_range(rng):
    x = make_data(rng, 128, 8, scale=3.0)
    k = np.asarray(kmatrix.kernel_matrix(jnp.asarray(x), p3(0.2, 0, 0), ref.RBF))
    assert k.min() >= 0.0 and k.max() <= 1.0 + 1e-6


def test_linear_equals_xxt(rng):
    x = make_data(rng, 256, 8)
    k = np.asarray(kmatrix.kernel_matrix(jnp.asarray(x), p3(0, 0, 0), ref.LINEAR))
    np.testing.assert_allclose(k, x @ x.T, rtol=3e-5, atol=3e-5)


def test_block_size_invariance(rng):
    """The tiling must not affect the numbers."""
    x = jnp.asarray(make_data(rng, 256, 8))
    k128 = kmatrix.kernel_matrix(x, p3(0.5, 0, 0), ref.RBF, block=128)
    k64 = kmatrix.kernel_matrix(x, p3(0.5, 0, 0), ref.RBF, block=64)
    k256 = kmatrix.kernel_matrix(x, p3(0.5, 0, 0), ref.RBF, block=256)
    np.testing.assert_allclose(k128, k64, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(k128, k256, rtol=1e-6, atol=1e-6)


def test_padding_rows_are_inert(rng):
    """Zero-padded rows must not change the valid Gram block (bucket
    padding contract used by the rust runtime)."""
    x = make_data(rng, 100, 4)
    xpad = np.zeros((128, 4), np.float32)
    xpad[:100] = x
    k_small = np.asarray(
        ref.kernel_matrix(jnp.asarray(x), ref.RBF, 0.5))
    k_pad = np.asarray(
        kmatrix.kernel_matrix(jnp.asarray(xpad), p3(0.5, 0, 0), ref.RBF))
    np.testing.assert_allclose(k_pad[:100, :100], k_small, rtol=3e-5, atol=3e-5)


def test_non_multiple_block_asserts(rng):
    x = jnp.asarray(make_data(rng, 100, 4))
    with pytest.raises(AssertionError):
        kmatrix.kernel_matrix(x, p3(0.5, 0, 0), ref.RBF, block=64)


# ------------------------------------------------------------- hypothesis sweep


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(FAMILIES),
    mexp=st.integers(1, 4),          # m = 64 * 2^mexp in {128..1024}
    d=st.sampled_from([1, 2, 3, 8, 17, 32]),
    g=st.floats(0.05, 2.0),
    c=st.floats(-1.0, 1.0),
    degree=st.sampled_from([1.0, 2.0, 3.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_sweep(kind, mexp, d, g, c, degree, seed):
    rng = np.random.default_rng(seed)
    m = 64 * 2**mexp
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    got = kmatrix.kernel_matrix(x, p3(g, c, degree), kind, block=64)
    want = ref.kernel_matrix(x, kind, g, c, degree)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(FAMILIES),
    m=st.sampled_from([64, 128, 256]),
    q=st.sampled_from([64, 128]),
    d=st.sampled_from([1, 2, 5, 8]),
    g=st.floats(0.05, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_cross_sweep(kind, m, q, d, g, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    xq = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    got = kmatrix.kernel_cross(x, xq, p3(g, 0.5, 2.0), kind, block=64)
    want = ref.kernel_cross(x, xq, kind, g, 0.5, 2.0)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
