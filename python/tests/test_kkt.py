"""L1 correctness: KKT sweep kernel vs oracle + case semantics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import kktsweep, ref

from .conftest import make_data


def p5(rho1, rho2, lo, hi, tol):
    return jnp.asarray([rho1, rho2, lo, hi, tol], jnp.float32)


def test_matches_ref(rng):
    m = 256
    x = make_data(rng, m, 4)
    kmat = ref.kernel_matrix(jnp.asarray(x), ref.RBF, 0.5)
    gamma = jnp.asarray((rng.normal(size=m) * 0.01).astype(np.float32))
    args = (-0.08, 0.3, -0.02, 0.04, 1e-6)
    v, fb = kktsweep.kkt_sweep(kmat, gamma, p5(*args))
    vr, fbr = ref.kkt_sweep(kmat, gamma, *args)
    np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(fb, fbr, rtol=1e-5, atol=1e-5)


def test_optimal_interior_point_has_zero_violation():
    """A gamma=0 point whose score is inside the slab satisfies KKT (49)."""
    # 2 points, identity kernel, gamma = (0, 0.5): s = (0, 0.5).
    kmat = jnp.eye(2, dtype=jnp.float32)
    gamma = jnp.asarray([0.0, 0.5], jnp.float32)
    # slab [-1, 1]: point 0 has s=0 inside -> viol 0.
    v, fb = kktsweep.kkt_sweep(kmat, gamma, p5(-1.0, 1.0, -0.3, 0.6, 1e-6),
                               block=2)
    assert float(v[0]) == 0.0
    # fbar = min(s - rho1, rho2 - s) = min(1, 1) = 1 for point 0
    np.testing.assert_allclose(float(fb[0]), 1.0, rtol=1e-6)


def test_free_sv_off_plane_is_violating():
    """A free 0<gamma<hi point must sit ON the lower plane (case (52))."""
    kmat = jnp.eye(2, dtype=jnp.float32)
    gamma = jnp.asarray([0.3, 0.0], jnp.float32)  # free in (0, hi=0.6)
    # s_0 = 0.3 but rho1 = 0.1 -> |s - rho1| = 0.2 violation.
    v, _ = kktsweep.kkt_sweep(kmat, gamma, p5(0.1, 1.0, -0.3, 0.6, 1e-6),
                              block=2)
    np.testing.assert_allclose(float(v[0]), 0.2, rtol=1e-5)


def test_bound_point_below_lower_plane():
    """gamma at upper bound hi is a lower-plane margin violator: its KKT
    condition is s <= rho1 (paper case (53), errata-corrected)."""
    kmat = jnp.eye(2, dtype=jnp.float32)
    gamma = jnp.asarray([0.6, 0.0], jnp.float32)  # at hi = 0.6
    # s_0 = 0.6 > rho1 = 0.1 -> violation 0.5
    v, _ = kktsweep.kkt_sweep(kmat, gamma, p5(0.1, 1.0, -0.3, 0.6, 1e-6),
                              block=2)
    np.testing.assert_allclose(float(v[0]), 0.5, rtol=1e-5)
    # and with rho1 above s the condition is satisfied
    v2, _ = kktsweep.kkt_sweep(kmat, gamma, p5(0.7, 1.0, -0.3, 0.6, 1e-6),
                               block=2)
    assert float(v2[0]) == 0.0


def test_bound_point_above_upper_plane():
    """gamma at lower bound lo is an upper-plane margin violator: its KKT
    condition is s >= rho2."""
    kmat = jnp.eye(2, dtype=jnp.float32)
    gamma = jnp.asarray([-0.3, 0.5], jnp.float32)  # at lo = -0.3
    # s_0 = -0.3 < rho2 = 0.2 -> violation 0.5
    v, _ = kktsweep.kkt_sweep(kmat, gamma, p5(-1.0, 0.2, -0.3, 0.6, 1e-6),
                              block=2)
    np.testing.assert_allclose(float(v[0]), 0.5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([64, 128, 256]),
    rho1=st.floats(-0.3, 0.1),
    width=st.floats(0.05, 0.8),
    nu1=st.floats(0.1, 0.9),
    nu2=st.floats(0.01, 0.2),
    eps=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kkt_sweep_hypothesis(m, rho1, width, nu1, nu2, eps, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, 3)).astype(np.float32))
    kmat = ref.kernel_matrix(x, ref.RBF, 0.7)
    lo, hi = -eps / (nu2 * m), 1.0 / (nu1 * m)
    gamma = jnp.asarray(rng.uniform(lo, hi, size=m).astype(np.float32))
    rho2 = rho1 + width
    v, fb = kktsweep.kkt_sweep(kmat, gamma, p5(rho1, rho2, lo, hi, 1e-6),
                               block=64)
    vr, fbr = ref.kkt_sweep(kmat, gamma, rho1, rho2, lo, hi, 1e-6)
    np.testing.assert_allclose(v, vr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(fb, fbr, rtol=1e-4, atol=1e-4)
