"""Pallas kernel: batched OCSSVM decision function (paper eq. (19)).

Serving hot path. For a query batch Xq[q, d] against a trained model
(support matrix X[m, d], dual vector gamma[m], offsets rho1/rho2):

    s_j   = sum_i gamma_i k(x_i, xq_j)
    f_j   = sign((s_j - rho1) * (rho2 - s_j))     # +1 inside the slab

The grid is 1-D over query tiles; each program contracts the FULL support
set against its (BQ, d) query tile:

    dots  = X @ xq_tile^T          # [m, BQ]  MXU contraction
    kc    = transform(dots, ...)   # fused VPU epilogue
    s     = gamma @ kc             # [BQ]     second MXU contraction
    f     = slab sign test         # fused

Keeping the reduction over m inside one program avoids a cross-program
accumulation (Pallas interpret mode has no atomic revisiting here and the
support set at paper scale — m <= 2048, d <= 32 — is ~256 KiB of VMEM, so
the whole X tile fits comfortably; for larger m the AOT path shards over
support-set buckets instead).

rho1/rho2 ride in the same length-5 scalar vector as the kernel
hyper-parameters: (g, c, degree, rho1, rho2). All stay runtime inputs so
one artifact serves every trained model of a given shape bucket.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .kmatrix import _transform_block

DEFAULT_QBLOCK = 64


def _decision_kernel(x_ref, g_ref, sq_ref, xq_ref, sqq_ref, p_ref,
                     s_ref, f_ref, *, kind):
    """Score one (BQ,) tile of queries against the full support set."""
    x = x_ref[...]        # [m, d]
    gamma = g_ref[...]    # [m]
    xq = xq_ref[...]      # [BQ, d]
    p = p_ref[...]        # [5] = (g, c, degree, rho1, rho2)
    rho1 = p[3]
    rho2 = p[4]

    dots = jnp.dot(x, xq.T, preferred_element_type=jnp.float32)  # [m, BQ]
    kc = _transform_block(dots, sq_ref[...], sqq_ref[...], p[:3], kind)
    s = jnp.dot(gamma, kc, preferred_element_type=jnp.float32)   # [BQ]
    inside = (s - rho1) * (rho2 - s)
    s_ref[...] = s
    f_ref[...] = jnp.where(inside >= 0.0, 1.0, -1.0)


def decision_scores(x, gamma, params5, xq, kind, qblock=DEFAULT_QBLOCK):
    """Batched decision function via pallas_call.

    Parameters
    ----------
    x      : [m, d] support matrix (zero rows for bucket padding).
    gamma  : [m] dual vector (0 on padded rows -> padding is inert).
    params5: [5] f32 — (g, c, degree, rho1, rho2).
    xq     : [q, d] query batch; q must be a multiple of ``qblock``.
    kind   : static int kernel family.

    Returns (scores[q], labels[q]).
    """
    m, d = x.shape
    q, dq = xq.shape
    assert d == dq
    bq = min(qblock, q)
    assert q % bq == 0
    sq = jnp.sum(x * x, axis=1)[:, None]       # [m, 1]
    sqq = jnp.sum(xq * xq, axis=1)[None, :]    # [1, q]

    grid = (q // bq,)
    return pl.pallas_call(
        functools.partial(_decision_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, d), lambda j: (0, 0)),   # full support set
            pl.BlockSpec((m,), lambda j: (0,)),       # full gamma
            pl.BlockSpec((m, 1), lambda j: (0, 0)),   # support sq-norms
            pl.BlockSpec((bq, d), lambda j: (j, 0)),  # query tile
            pl.BlockSpec((1, bq), lambda j: (0, j)),  # query sq-norms
            pl.BlockSpec((5,), lambda j: (0,)),       # scalars
        ],
        out_specs=[
            pl.BlockSpec((bq,), lambda j: (j,)),
            pl.BlockSpec((bq,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.float32),
            jax.ShapeDtypeStruct((q,), jnp.float32),
        ],
        interpret=True,
    )(x, gamma, sq, xq, sqq, params5)
