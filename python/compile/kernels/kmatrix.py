"""Pallas kernel: tiled Gram-matrix computation.

Computes K[i,j] = k(x_i, x_j) for the four kernel families used by the
OCSSVM (linear / rbf / polynomial / sigmoid) as a 2-D grid of block
programs. Each program owns one (BI, BJ) output tile:

    grid = (m/BI, m/BJ)
    program (i, j):
        dots  = X[i*BI:(i+1)*BI, :] @ X[j*BJ:(j+1)*BJ, :]^T   # MXU matmul
        K_ij  = transform(dots, ||x_i||^2, ||x_j||^2)          # fused VPU

This is the TPU shape of the paper's compute hot-spot (kernel evaluation
dominates SMO + serving): the (BI,d)x(d,BJ) contraction is MXU-shaped,
and the elementwise kernel transform (exp/tanh/pow) is fused into the
same program while the tile is VMEM-resident — the TPU analogue of the
fused-epilogue GEMM that GPU SVM implementations use (DESIGN.md
§Hardware-Adaptation).

VMEM per program (f32): BI*d + BJ*d + BI*BJ + BI + BJ words. At the
default BI=BJ=128 and d<=512 this is under 1 MiB, far inside the ~16 MiB
VMEM budget, leaving room for double-buffering the X tiles.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
executes on the rust CPU client (see /opt/xla-example/README.md).

Hyper-parameters (g, c, degree) arrive as a length-3 f32 vector so they
stay runtime inputs of the lowered HLO — one artifact serves an entire
hyper-parameter sweep. The kernel *family* is a static python int and
selects the fused transform at trace time (one artifact per family).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile edge: MXU-native 128.
DEFAULT_BLOCK = 128


def _transform_block(dots, sq_i, sq_j, params, kind):
    """Fused elementwise kernel transform on one VMEM-resident tile."""
    g = params[0]
    c = params[1]
    degree = params[2]
    if kind == ref.LINEAR:
        return dots
    if kind == ref.RBF:
        d2 = jnp.maximum(sq_i + sq_j - 2.0 * dots, 0.0)
        return jnp.exp(-g * d2)
    if kind == ref.POLY:
        return jnp.power(g * dots + c, degree)
    if kind == ref.SIGMOID:
        return jnp.tanh(g * dots + c)
    raise ValueError(f"unknown kernel id {kind}")


def _kmatrix_kernel(xi_ref, xj_ref, sqi_ref, sqj_ref, p_ref, o_ref, *, kind):
    """One (BI, BJ) Gram tile: MXU contraction + fused transform."""
    xi = xi_ref[...]  # [BI, d]
    xj = xj_ref[...]  # [BJ, d]
    dots = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    o_ref[...] = _transform_block(
        dots, sqi_ref[...], sqj_ref[...], p_ref[...], kind
    )


def kernel_matrix(x, params, kind, block=DEFAULT_BLOCK):
    """Tiled Gram matrix via pallas_call.

    Parameters
    ----------
    x : [m, d] f32. m must be a multiple of ``block`` (the AOT path pads
        to a shape bucket; padded rows are zero and produce K entries that
        downstream contractions ignore because their gamma is 0).
    params : [3] f32 — (g, c, degree).
    kind : static int kernel family.
    """
    m, d = x.shape
    bi = bj = min(block, m)
    assert m % bi == 0, f"m={m} not a multiple of block={bi}"
    sq = jnp.sum(x * x, axis=1)
    sq_col = sq[:, None]  # [m, 1]
    sq_row = sq[None, :]  # [1, m]

    grid = (m // bi, m // bj)
    return pl.pallas_call(
        functools.partial(_kmatrix_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, d), lambda i, j: (i, 0)),  # row tile of X
            pl.BlockSpec((bj, d), lambda i, j: (j, 0)),  # col tile of X
            pl.BlockSpec((bi, 1), lambda i, j: (i, 0)),  # row sq-norms
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),  # col sq-norms
            pl.BlockSpec((3,), lambda i, j: (0,)),  # hyper-params
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=True,
    )(x, x, sq_col, sq_row, params)


def _kcross_kernel(xi_ref, xq_ref, sqi_ref, sqq_ref, p_ref, o_ref, *, kind):
    """One (BI, BQ) cross-kernel tile K[i, q] = k(x_i, xq_q)."""
    xi = xi_ref[...]
    xq = xq_ref[...]
    dots = jnp.dot(xi, xq.T, preferred_element_type=jnp.float32)
    o_ref[...] = _transform_block(
        dots, sqi_ref[...], sqq_ref[...], p_ref[...], kind
    )


def kernel_cross(x, xq, params, kind, block=DEFAULT_BLOCK):
    """Tiled cross-kernel matrix K[m, q] via pallas_call."""
    m, d = x.shape
    q, dq = xq.shape
    assert d == dq
    bi = min(block, m)
    bq = min(block, q)
    assert m % bi == 0 and q % bq == 0
    sq = jnp.sum(x * x, axis=1)[:, None]  # [m, 1]
    sqq = jnp.sum(xq * xq, axis=1)[None, :]  # [1, q]

    grid = (m // bi, q // bq)
    return pl.pallas_call(
        functools.partial(_kcross_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bi, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bi, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, q), jnp.float32),
        interpret=True,
    )(x, xq, sq, sqq, params)
