"""Pallas kernel: vectorized KKT sweep (paper eqs. (49)-(53) + (56)).

The paper's working-set heuristic needs, every outer iteration, the KKT
violation magnitude of every training point plus the selection score
f_bar(x) = min(s - rho1, rho2 - s) (eq. 56). Done naively this is an
O(m^2) scan per iteration; the rust solver keeps s = K gamma incrementally
updated, but the *initial* sweep and periodic full re-validations are
batch jobs — this kernel is that batch job, shipped to PJRT.

Grid is 1-D over row tiles of the Gram matrix:

    program i:
        s_tile    = K[i*B:(i+1)*B, :] @ gamma          # MXU contraction
        viol_tile = per-case KKT violation (fused select tree)
        fbar_tile = min(s - rho1, rho2 - s)

Scalars ride in a length-5 vector (rho1, rho2, lo, hi, tol) where
lo = -eps/(nu2 m) and hi = 1/(nu1 m) are the gamma box bounds (31).

The case analysis mirrors ref.kkt_sweep exactly; see that docstring for
the margin-unit semantics of each branch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _kkt_kernel(k_ref, g_ref, gi_ref, p_ref, v_ref, f_ref):
    """KKT violation + f_bar for one row tile."""
    krows = k_ref[...]     # [B, m]
    gamma = g_ref[...]     # [m]
    gi = gi_ref[...]       # [B]   gamma restricted to this tile
    p = p_ref[...]         # [5] = (rho1, rho2, lo, hi, tol)
    rho1, rho2, lo, hi, tol = p[0], p[1], p[2], p[3], p[4]

    s = jnp.dot(krows, gamma, preferred_element_type=jnp.float32)  # [B]

    at_zero = jnp.abs(gi) <= tol
    at_lo = (~at_zero) & (gi <= lo + tol)
    at_hi = (~at_zero) & (gi >= hi - tol)
    on_upper = (~at_zero) & (~at_lo) & (gi < 0.0)

    v_lo = jnp.maximum(rho2 - s, 0.0)  # gamma at lo: need s >= rho2
    v_hi = jnp.maximum(s - rho1, 0.0)  # gamma at hi: need s <= rho1
    v_up = jnp.abs(s - rho2)
    v_dn = jnp.abs(s - rho1)
    v_in = jnp.maximum(rho1 - s, 0.0) + jnp.maximum(s - rho2, 0.0)

    viol = jnp.where(
        at_zero,
        v_in,
        jnp.where(
            at_lo,
            v_lo,
            jnp.where(at_hi, v_hi, jnp.where(on_upper, v_up, v_dn)),
        ),
    )
    v_ref[...] = viol
    f_ref[...] = jnp.minimum(s - rho1, rho2 - s)


def kkt_sweep(kmat, gamma, params5, block=DEFAULT_BLOCK):
    """Full-dataset KKT sweep via pallas_call.

    Parameters
    ----------
    kmat   : [m, m] Gram matrix (padded rows/cols carry gamma=0).
    gamma  : [m] dual vector.
    params5: [5] f32 — (rho1, rho2, lo, hi, tol).

    Returns (viol[m], fbar[m]).
    """
    m = gamma.shape[0]
    b = min(block, m)
    assert m % b == 0

    grid = (m // b,)
    return pl.pallas_call(
        _kkt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, m), lambda i: (i, 0)),  # row tile of K
            pl.BlockSpec((m,), lambda i: (0,)),      # full gamma
            pl.BlockSpec((b,), lambda i: (i,)),      # tile's own gamma
            pl.BlockSpec((5,), lambda i: (0,)),      # scalars
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=True,
    )(kmat, gamma, gamma, params5)
