"""L1: Pallas kernels for the OCSSVM hot-spots + their pure-jnp oracle.

Modules
-------
ref       pure-jnp reference implementations (the correctness oracle)
kmatrix   tiled Gram / cross-kernel matrix kernels
decision  batched slab decision function (serving hot path)
kktsweep  vectorized KKT-violation + f_bar sweep (working-set scan)
"""

from . import decision, kktsweep, kmatrix, ref  # noqa: F401

__all__ = ["ref", "kmatrix", "decision", "kktsweep"]
