"""Pure-jnp reference oracle for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with nothing but jax.numpy so it is trivially auditable. The
pytest suite (python/tests/) asserts allclose(pallas, ref) across a
hypothesis sweep of shapes, dtypes and kernel hyper-parameters — this is
the core L1 correctness signal.

Conventions
-----------
* ``x``:  [m, d] training/support matrix (rows are samples).
* ``xq``: [q, d] query matrix.
* ``gamma``: [m] dual coefficient vector (gamma_i = alpha_i - alpha_bar_i
  in the paper's eq. (30) re-parameterization).
* kernel hyper-parameters are passed as scalars so the lowered artifact
  serves a whole hyper-parameter sweep (nothing is baked into the HLO).

Kernel ids (must match kernels/kmatrix.py and rust/src/kernel/):
    0 = linear      k(x,y) = <x,y>
    1 = rbf         k(x,y) = exp(-g * ||x-y||^2)
    2 = polynomial  k(x,y) = (g * <x,y> + c)^degree
    3 = sigmoid     k(x,y) = tanh(g * <x,y> + c)
"""

from __future__ import annotations

import jax.numpy as jnp

# Kernel-id constants, shared with the Pallas implementations.
LINEAR, RBF, POLY, SIGMOID = 0, 1, 2, 3


def kernel_transform(dots, sq_i, sq_j, kind, g, c, degree):
    """Apply the kernel function to a block of raw inner products.

    Parameters
    ----------
    dots : [bi, bj] raw inner products <x_i, x_j>.
    sq_i : [bi, 1] squared norms ||x_i||^2 (only used by RBF).
    sq_j : [1, bj] squared norms ||x_j||^2 (only used by RBF).
    kind : python int kernel id (static — selects the branch at trace time).
    g, c, degree : scalar hyper-parameters (traced).
    """
    if kind == LINEAR:
        return dots
    if kind == RBF:
        d2 = jnp.maximum(sq_i + sq_j - 2.0 * dots, 0.0)
        return jnp.exp(-g * d2)
    if kind == POLY:
        return jnp.power(g * dots + c, degree)
    if kind == SIGMOID:
        return jnp.tanh(g * dots + c)
    raise ValueError(f"unknown kernel id {kind}")


def kernel_matrix(x, kind, g=1.0, c=0.0, degree=3.0):
    """Full Gram matrix K[i,j] = k(x_i, x_j).  [m,d] -> [m,m]."""
    dots = x @ x.T
    sq = jnp.sum(x * x, axis=1)
    return kernel_transform(dots, sq[:, None], sq[None, :], kind, g, c, degree)


def kernel_cross(x, xq, kind, g=1.0, c=0.0, degree=3.0):
    """Cross-kernel K[i,j] = k(x_i, xq_j).  ([m,d],[q,d]) -> [m,q]."""
    dots = x @ xq.T
    sq = jnp.sum(x * x, axis=1)
    sqq = jnp.sum(xq * xq, axis=1)
    return kernel_transform(dots, sq[:, None], sqq[None, :], kind, g, c, degree)


def decision_scores(x, gamma, rho1, rho2, xq, kind, g=1.0, c=0.0, degree=3.0):
    """Batch decision function of the OCSSVM (paper eq. (19)).

    Returns
    -------
    scores : [q]   s_j   = sum_i gamma_i k(x_i, xq_j)
    labels : [q]   f(xq) = sign((s - rho1) * (rho2 - s)); +1 inside the
             slab, -1 outside (0 mapped to +1: on-plane points are inside).
    """
    kc = kernel_cross(x, xq, kind, g, c, degree)  # [m, q]
    s = gamma @ kc  # [q]
    inside = (s - rho1) * (rho2 - s)
    labels = jnp.where(inside >= 0.0, 1.0, -1.0)
    return s, labels


def kkt_sweep(kmat, gamma, rho1, rho2, lo, hi, tol):
    """Vectorized KKT scan over all training points (paper eqs. (49)-(53)).

    Given the full Gram matrix, the dual vector and the current slab
    offsets, compute for every i:

      fbar[i]  = min(s_i - rho1, rho2 - s_i)          (paper eq. (56))
      viol[i]  = KKT violation magnitude, in margin units (paper cases
      (49)-(53) with the errata fixes of DESIGN.md §1.1; gamma maps to
      the (alpha, alpha_bar) blocks under the exclusivity property):
            gamma_i ~ lo (alpha_bar at cap) -> need s_i >= rho2 (upper
                                               -plane margin violator)
            gamma_i ~ hi (alpha at cap)     -> need s_i <= rho1 (lower
                                               -plane margin violator)
            lo < gamma_i < 0 (free ab-SV)   -> need s_i == rho2
            0 < gamma_i < hi (free a-SV)    -> need s_i == rho1
            gamma_i ~ 0  (interior)         -> need rho1 <= s_i <= rho2

    where s = K gamma.  ``lo = -eps/(nu2 m)``, ``hi = 1/(nu1 m)``.
    Returns (viol, fbar).
    """
    s = kmat @ gamma
    at_zero = jnp.abs(gamma) <= tol
    at_lo = (~at_zero) & (gamma <= lo + tol)
    at_hi = (~at_zero) & (gamma >= hi - tol)
    on_upper = (~at_zero) & (~at_lo) & (gamma < 0.0)

    # Violation in each KKT case; clamped at 0 when satisfied.
    v_lo = jnp.maximum(rho2 - s, 0.0)  # above-slab margin violator
    v_hi = jnp.maximum(s - rho1, 0.0)  # below-slab margin violator
    v_up = jnp.abs(s - rho2)  # free SV must sit ON the upper plane
    v_dn = jnp.abs(s - rho1)  # free SV must sit ON the lower plane
    v_in = jnp.maximum(rho1 - s, 0.0) + jnp.maximum(s - rho2, 0.0)

    viol = jnp.where(
        at_zero,
        v_in,
        jnp.where(
            at_lo,
            v_lo,
            jnp.where(at_hi, v_hi, jnp.where(on_upper, v_up, v_dn)),
        ),
    )
    fbar = jnp.minimum(s - rho1, rho2 - s)
    return viol, fbar
