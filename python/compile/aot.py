"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts for the rust runtime.

Emits HLO *text*, not serialized HloModuleProto: jax >= 0.5 writes protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`). The
text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py there.

Artifact set (DESIGN.md §2, Layer 2):

  kmatrix_<fam>_m<M>_d<D>.hlo.txt       (x[M,D], p3)            -> (K,)
  decision_<fam>_m<M>_d<D>_q<Q>.hlo.txt (x, gamma, p5, xq[Q,D]) -> (s, f)
  kkt_m<M>.hlo.txt                      (K[M,M], gamma, p5)     -> (v, fbar)

plus artifacts/manifest.json describing every artifact's entry shapes so
the rust runtime can do shape-bucket selection without parsing HLO.

Run via `make artifacts` (no-op when inputs are unchanged, courtesy of
make's dependency tracking). Python never runs after this point.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

FAMILY_NAMES = {0: "linear", 1: "rbf", 2: "poly", 3: "sigmoid"}

# Default shape buckets (padding handled by the rust runtime).
M_BUCKETS = [256, 512, 1024, 2048]
D_BUCKETS = [2, 8]
Q_BUCKETS = [64, 256]
DEFAULT_FAMILIES = [0, 1]  # linear (the paper's kernel) + rbf (examples)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_all(out_dir: pathlib.Path, families, m_buckets, d_buckets,
              q_buckets, verbose=True):
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}

    def emit(name, lowered, entry):
        t0 = time.time()
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        entry["file"] = path.name
        entry["bytes"] = len(text)
        manifest["artifacts"].append(entry)
        if verbose:
            print(f"  {path.name:44s} {len(text)/1024:8.1f} KiB "
                  f"({time.time()-t0:.2f}s)")

    for fam in families:
        fname = FAMILY_NAMES[fam]
        for m in m_buckets:
            for d in d_buckets:
                lowered = jax.jit(model.kmatrix_fn(fam)).lower(
                    _spec(m, d), _spec(3))
                emit(f"kmatrix_{fname}_m{m}_d{d}", lowered, {
                    "kind": "kmatrix", "family": fname, "m": m, "d": d,
                    "inputs": [[m, d], [3]], "outputs": [[m, m]],
                })
                for q in q_buckets:
                    lowered = jax.jit(model.decision_fn(fam)).lower(
                        _spec(m, d), _spec(m), _spec(5), _spec(q, d))
                    emit(f"decision_{fname}_m{m}_d{d}_q{q}", lowered, {
                        "kind": "decision", "family": fname,
                        "m": m, "d": d, "q": q,
                        "inputs": [[m, d], [m], [5], [q, d]],
                        "outputs": [[q], [q]],
                    })

    for m in m_buckets:
        lowered = jax.jit(model.kkt_fn()).lower(
            _spec(m, m), _spec(m), _spec(5))
        emit(f"kkt_m{m}", lowered, {
            "kind": "kkt", "family": "any", "m": m,
            "inputs": [[m, m], [m], [5]], "outputs": [[m], [m]],
        })

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--full", action="store_true",
                    help="emit all four kernel families (default: linear+rbf)")
    ap.add_argument("--quick", action="store_true",
                    help="small bucket set for CI smoke runs")
    args = ap.parse_args()

    families = list(FAMILY_NAMES) if args.full else DEFAULT_FAMILIES
    m_buckets = [256, 512] if args.quick else M_BUCKETS
    q_buckets = [64] if args.quick else Q_BUCKETS
    d_buckets = [2] if args.quick else D_BUCKETS

    out = pathlib.Path(args.out)
    t0 = time.time()
    lower_all(out, families, m_buckets, d_buckets, q_buckets)
    print(f"total {time.time()-t0:.1f}s -> {out.resolve()}")


if __name__ == "__main__":
    main()
