"""L2: JAX compute graphs for the OCSSVM, composed from the L1 kernels.

Three jitted entry points, one per AOT artifact family (DESIGN.md §2):

  kmatrix_fn   (X[m,d], params3)                        -> (K[m,m],)
  decision_fn  (X[m,d], gamma[m], params5, Xq[q,d])     -> (scores[q], labels[q])
  kkt_fn       (K[m,m], gamma[m], params5)              -> (viol[m], fbar[m])

All hyper-parameters (kernel g/c/degree, rho1/rho2, KKT bounds/tol) are
runtime inputs — nothing numeric is baked into the HLO except shapes and
the kernel *family* (the elementwise transform branch), so one artifact
per (family, shape-bucket) serves every trained model and every sweep
point. Shape buckets are padded by the rust runtime; padded rows carry
gamma = 0, which makes them inert in every contraction these graphs
perform (the Gram rows of padding are garbage-free: zero rows give k=0
for linear/poly/sigmoid-with-c=0 and a constant for RBF, but are never
read with nonzero weight).

Python here is build-time only: `aot.py` lowers these functions once to
HLO text; the rust runtime loads and executes the artifacts via PJRT.
"""

from __future__ import annotations

import jax

from .kernels import decision, kktsweep, kmatrix


def kmatrix_fn(kind: int):
    """Gram-matrix graph for kernel family ``kind``.

    Returned callable: (x[m,d], params3) -> (K[m,m],). Tuple-wrapped so
    the HLO root is a tuple (the rust loader unwraps with to_tuple1).
    """

    @jax.jit
    def fn(x, params3):
        return (kmatrix.kernel_matrix(x, params3, kind),)

    return fn


def decision_fn(kind: int):
    """Serving graph: batch slab decision function (paper eq. (19)).

    Returned callable:
        (x[m,d], gamma[m], params5, xq[q,d]) -> (scores[q], labels[q])
    with params5 = (g, c, degree, rho1, rho2).
    """

    @jax.jit
    def fn(x, gamma, params5, xq):
        return decision.decision_scores(x, gamma, params5, xq, kind)

    return fn


def kkt_fn():
    """KKT sweep graph (kernel-family independent — consumes K directly).

    Returned callable:
        (kmat[m,m], gamma[m], params5) -> (viol[m], fbar[m])
    with params5 = (rho1, rho2, lo, hi, tol).
    """

    @jax.jit
    def fn(kmat, gamma, params5):
        return kktsweep.kkt_sweep(kmat, gamma, params5)

    return fn
