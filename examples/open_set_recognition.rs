//! Open-set recognition: accept one known class, reject unseen classes.
//!
//! The paper's motivating problem (its refs [6][12]): training sees only
//! class 0; at test time samples from k−1 *unseen* classes appear and
//! must be rejected. We train one OCSSVM on class 0 and evaluate on the
//! full mixture, sweeping the slab-width parameters to show the
//! precision/recall trade-off nu1/nu2 control. RBF kernel — class
//! regions are radial blobs, not half-spaces. Everything runs through
//! the unified `Trainer` API.
//!
//! ```bash
//! cargo run --release --example open_set_recognition
//! ```

use slabsvm::data::synthetic::open_set;
use slabsvm::kernel::Kernel;
use slabsvm::metrics::roc_auc;
use slabsvm::solver::{SolverKind, Trainer};

fn main() -> slabsvm::Result<()> {
    // 6 classes on a circle; class 0 is the known one.
    let scenario = open_set(6, 6.0, 0.6, 800, 1200, 9);
    println!(
        "train: {} samples of class 0 | eval: {} samples, {} positives",
        scenario.train.len(),
        scenario.eval.len(),
        scenario.eval.positives()
    );

    let kernel = Kernel::Rbf { g: 0.35 };

    println!("\nOCSSVM parameter sweep (RBF g=0.35):");
    println!(
        "{:>6} {:>6} {:>6} | {:>7} {:>7} {:>7} {:>7}",
        "nu1", "nu2", "eps", "MCC", "F1", "prec", "recall"
    );
    let mut best = (f64::MIN, 0.0, 0.0, 0.0);
    for &(nu1, nu2, eps) in &[
        (0.05, 0.05, 0.5),
        (0.1, 0.05, 0.5),
        (0.1, 0.1, 0.3),
        (0.2, 0.1, 0.5),
        (0.3, 0.2, 0.5),
    ] {
        let report = Trainer::new(SolverKind::Smo)
            .kernel(kernel)
            .nu1(nu1)
            .nu2(nu2)
            .eps(eps)
            .fit(&scenario.train.x)?;
        let c = report.model.evaluate(&scenario.eval);
        println!(
            "{nu1:>6} {nu2:>6} {eps:>6} | {:>7.3} {:>7.3} {:>7.3} {:>7.3}",
            c.mcc(),
            c.f1(),
            c.precision(),
            c.recall()
        );
        if c.mcc() > best.0 {
            best = (c.mcc(), nu1, nu2, eps);
        }
    }
    println!(
        "best MCC {:.3} at nu1={} nu2={} eps={}",
        best.0, best.1, best.2, best.3
    );

    // Margin-based ranking quality (threshold-free view).
    let report = Trainer::new(SolverKind::Smo)
        .kernel(kernel)
        .nu1(best.1)
        .nu2(best.2)
        .eps(best.3)
        .fit(&scenario.train.x)?;
    let margins: Vec<f64> = (0..scenario.eval.len())
        .map(|i| report.model.margin(scenario.eval.x.row(i)))
        .collect();
    println!(
        "ROC-AUC of the slab margin: {:.3}",
        roc_auc(&scenario.eval.y, &margins)
    );

    // Baseline: single-plane OCSVM at a comparable operating point —
    // same API, different SolverKind.
    let ocsvm = Trainer::new(SolverKind::OcsvmSmo)
        .kernel(kernel)
        .nu1(best.1)
        .fit(&scenario.train.x)?;
    let c = ocsvm.model.evaluate(&scenario.eval);
    println!(
        "OCSVM baseline (nu={}): MCC={:.3} F1={:.3}",
        best.1,
        c.mcc(),
        c.f1()
    );
    Ok(())
}
