//! Cascade (parallel) SMO — the paper's future-work item, refs [4][31].
//!
//! Trains the OCSSVM by sharding the data across threads, then retraining
//! on the union of shard support vectors (ν rescaled so the subset solve
//! matches the full dual — see solver/cascade.rs). Compares wall-clock
//! and objective against the direct solve, at an SV-sparse operating
//! point (ν₁ = 0.1) and at the paper's ν₁ = 0.5 (where half the data are
//! SVs and the cascade cannot shrink the problem — an honest negative
//! result).
//!
//! ```bash
//! cargo run --release --example cascade_training
//! ```

use std::time::Instant;

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::cascade::{self, CascadeParams};
use slabsvm::solver::smo::{train_full, SmoParams};

fn main() -> slabsvm::Result<()> {
    let m = 6000;
    let ds = SlabConfig::default().generate(m, 2025);

    for (label, nu1) in [("sparse SVs (nu1=0.1)", 0.1), ("paper constants (nu1=0.5)", 0.5)] {
        println!("\n=== {label} ===");
        let smo = SmoParams { nu1, nu2: 0.05, eps: 0.5, ..Default::default() };

        let t0 = Instant::now();
        let (direct_model, direct) = train_full(&ds.x, Kernel::Linear, &smo)?;
        let t_direct = t0.elapsed().as_secs_f64();
        println!(
            "direct : {t_direct:.3}s, obj {:.4}, {} SVs",
            direct.stats.objective,
            direct_model.n_sv()
        );

        for shards in [2usize, 4, 8] {
            let t0 = Instant::now();
            let p = CascadeParams { smo, shards, max_rounds: 4 };
            let (model, casc) = cascade::train(&ds.x, Kernel::Linear, &p)?;
            let t_casc = t0.elapsed().as_secs_f64();
            let rel = (casc.outcome.stats.objective - direct.stats.objective).abs()
                / direct.stats.objective.abs().max(1e-9);
            println!(
                "casc x{shards}: {t_casc:.3}s ({:.2}x), obj {:.4} (Δ {:.1e}), \
                 union {} -> {} SVs, {} rounds",
                t_direct / t_casc,
                casc.outcome.stats.objective,
                rel,
                casc.candidate_sizes[0],
                model.n_sv(),
                casc.rounds,
            );
        }
    }
    println!(
        "\ntakeaway: the cascade pays off exactly when the SV fraction is small;\n\
         at the paper's own nu1 = 0.5 half the data are SVs by construction and\n\
         the union cannot shrink — parallel SMO (the paper's suggestion) needs\n\
         sparse-SV operating points to help."
    );
    Ok(())
}
