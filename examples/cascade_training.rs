//! Cascade (parallel) SMO — the paper's future-work item, refs [4][31].
//!
//! Trains the OCSSVM by sharding the data across threads, then retraining
//! on the union of shard support vectors (ν rescaled so the subset solve
//! matches the full dual — see solver/cascade.rs). In the unified API the
//! cascade is a `Trainer` layer: `.cascade(shards, max_rounds)` on top of
//! any solver kind. Compares wall-clock and objective against the direct
//! solve, at an SV-sparse operating point (ν₁ = 0.1) and at the paper's
//! ν₁ = 0.5 (where half the data are SVs and the cascade cannot shrink
//! the problem — an honest negative result).
//!
//! ```bash
//! cargo run --release --example cascade_training
//! ```

use std::time::Instant;

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{SolverKind, Trainer};

fn main() -> slabsvm::Result<()> {
    let m = 6000;
    let ds = SlabConfig::default().generate(m, 2025);

    for (label, nu1) in [("sparse SVs (nu1=0.1)", 0.1), ("paper constants (nu1=0.5)", 0.5)] {
        println!("\n=== {label} ===");
        let base = Trainer::new(SolverKind::Smo)
            .kernel(Kernel::Linear)
            .nu1(nu1)
            .nu2(0.05)
            .eps(0.5);

        let t0 = Instant::now();
        let direct = base.fit(&ds.x)?;
        let t_direct = t0.elapsed().as_secs_f64();
        println!(
            "direct : {t_direct:.3}s, obj {:.4}, {} SVs",
            direct.stats.objective,
            direct.model.n_sv()
        );

        for shards in [2usize, 4, 8] {
            let t0 = Instant::now();
            let casc = base.clone().cascade(shards, 4).fit(&ds.x)?;
            let t_casc = t0.elapsed().as_secs_f64();
            let trace = casc.cascade.as_ref().expect("cascade trace");
            let rel = (casc.stats.objective - direct.stats.objective).abs()
                / direct.stats.objective.abs().max(1e-9);
            println!(
                "casc x{shards}: {t_casc:.3}s ({:.2}x), obj {:.4} (Δ {:.1e}), \
                 union {} -> {} SVs, {} rounds",
                t_direct / t_casc,
                casc.stats.objective,
                rel,
                trace.candidate_sizes[0],
                casc.model.n_sv(),
                trace.rounds,
            );
        }
    }
    println!(
        "\ntakeaway: the cascade pays off exactly when the SV fraction is small;\n\
         at the paper's own nu1 = 0.5 half the data are SVs by construction and\n\
         the union cannot shrink — parallel SMO (the paper's suggestion) needs\n\
         sparse-SV operating points to help."
    );
    Ok(())
}
