//! Serving pipeline: train via the job queue, score through the batcher.
//!
//! Demonstrates the full L3 coordinator with the PJRT engine when
//! artifacts are present (falls back to native otherwise): async train
//! job → model registry → dynamically batched scoring under a bursty
//! synthetic workload → service stats. Training jobs carry a full
//! `Trainer`, so heterogeneous tenants (different solvers, kernels,
//! layers) run through one queue.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_pipeline
//! ```

use std::time::Instant;

use slabsvm::coordinator::{BatcherConfig, Coordinator, JobStatus, TrainRequest};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::{SolverKind, Trainer};

fn main() -> slabsvm::Result<()> {
    // PJRT engine if artifacts exist, else native.
    let engine = match Engine::pjrt("artifacts") {
        Ok(e) => {
            println!("engine: pjrt (AOT artifacts loaded)");
            e
        }
        Err(e) => {
            println!("engine: native (pjrt unavailable: {e})");
            Engine::Native
        }
    };

    let coordinator = Coordinator::start(
        engine,
        BatcherConfig { max_batch: 256, max_wait_us: 800, queue_cap: 16384 },
        2,
    );

    // Train two model variants asynchronously (two tenants) — one on the
    // paper's SMO, one warm-started, through the same job queue.
    let mut jobs = Vec::new();
    for (name, nu1, warm) in [("tenant-a", 0.5, 0), ("tenant-b", 0.2, 2)] {
        let ds = SlabConfig::default().generate(1000, 42);
        let trainer = Trainer::new(SolverKind::Smo)
            .kernel(Kernel::Linear)
            .nu1(nu1)
            .warm_start(warm);
        jobs.push((
            name,
            coordinator.submit_train(TrainRequest {
                name: name.into(),
                dataset: ds,
                trainer,
            }),
        ));
    }
    for (name, id) in jobs {
        match coordinator.wait_job(id) {
            Some(JobStatus::Done { iterations, seconds, n_sv, version }) => {
                println!(
                    "{name}: trained v{version} in {iterations} iters \
                     ({seconds:.3}s), {n_sv} SVs"
                );
            }
            other => panic!("{name} failed: {other:?}"),
        }
    }

    // Bursty workload: rounds of concurrent requests against both models.
    let eval = SlabConfig::default().generate_eval(2000, 2000, 99);
    let t0 = Instant::now();
    let mut total = 0usize;
    for round in 0..8 {
        let mut rxs = Vec::new();
        for i in 0..500 {
            let idx = (round * 500 + i) % eval.len();
            let model = if i % 2 == 0 { "tenant-a" } else { "tenant-b" };
            rxs.push(coordinator.score_async(model, vec![eval.x.row(idx).to_vec()]));
        }
        for rx in rxs {
            rx.recv().expect("batcher alive")?;
            total += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {total} requests in {dt:.3}s ({:.0} req/s)",
        total as f64 / dt
    );
    println!("service stats: {}", coordinator.stats().summary());
    println!(
        "batching efficiency: {:.1} queries per engine dispatch",
        coordinator.stats().mean_batch_size()
    );

    coordinator.shutdown();
    Ok(())
}
