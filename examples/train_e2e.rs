//! End-to-end validation driver (DESIGN.md experiment index, §E2E).
//!
//! Exercises every layer of the system on the paper's workload at every
//! Table-1 size: synthetic data → unified-API training → independent KKT
//! certification → MCC evaluation → model persistence → serving through
//! the coordinator (PJRT engine when artifacts are present) → engine
//! equivalence check (native vs PJRT scores). Prints the Table-1 rows
//! with the paper's reported values alongside.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e
//! ```

use std::sync::Arc;
use std::time::Instant;

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::validate::certify;
use slabsvm::solver::{SolverKind, Trainer};

const PAPER: &[(usize, f64, f64)] = &[
    (500, 0.35, 0.07),
    (1000, 0.67, 0.13),
    (2000, 2.1, 0.26),
    (5000, 5.91, 0.33),
];

fn main() -> slabsvm::Result<()> {
    let pjrt = Engine::pjrt("artifacts").ok();
    println!(
        "end-to-end driver | engines: native{}",
        if pjrt.is_some() { " + pjrt" } else { " (pjrt unavailable)" }
    );
    // the paper's constants are the Trainer defaults; pull them back out
    // so the independent certification checks the exact trained problem
    let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
    let smo = trainer.smo_params();
    let (nu1, nu2, eps) = (smo.nu1, smo.nu2, smo.eps);

    println!(
        "\n{:>6} {:>10} {:>8} {:>8} {:>10} {:>12} {:>12}",
        "m", "time(s)", "MCC", "SVs", "iters", "paper t(s)", "paper MCC"
    );

    let coordinator =
        Coordinator::start(Engine::Native, BatcherConfig::default(), 2);

    for &(m, paper_t, paper_mcc) in PAPER {
        let ds = SlabConfig::default().generate(m, 1000 + m as u64);

        // train (L3 solver over the native Gram, unified API)
        let report = trainer.fit(&ds.x)?;
        let model = &report.model;

        // certify against an independently computed Gram matrix (the
        // report's built-in certificate reuses the solver's margins;
        // this one recomputes everything from scratch)
        let k = Kernel::Linear.gram(&ds.x, 4);
        certify(
            &k,
            &report.dual.alpha,
            &report.dual.alpha_bar,
            report.dual.rho1,
            report.dual.rho2,
            nu1,
            nu2,
            eps,
            1e-2 * (1.0 + report.dual.rho2.abs()),
        )
        .expect("solution must certify");

        // evaluate
        let eval = SlabConfig::default().generate_eval(m / 2, m / 2, 7 + m as u64);
        let cm = model.evaluate(&eval);

        // persist + reload
        let path = format!("/tmp/slabsvm_e2e_{m}.json");
        model.save(&path)?;
        let reloaded = slabsvm::solver::ocssvm::SlabModel::load(&path)?;

        // serve through the coordinator
        let name = format!("e2e-{m}");
        coordinator.register(&name, reloaded);
        let queries: Vec<Vec<f64>> =
            (0..eval.len().min(256)).map(|i| eval.x.row(i).to_vec()).collect();
        let resp = coordinator.score(&name, queries.clone())?;
        for (i, &label) in resp.labels.iter().enumerate() {
            assert_eq!(label, model.classify(eval.x.row(i)), "serving mismatch");
        }

        // engine equivalence: PJRT scores must match native (f32 tol)
        if let Some(pjrt) = &pjrt {
            let arc = Arc::new(model.clone());
            let sub = eval.select(&(0..128).collect::<Vec<_>>());
            let t_pjrt = Instant::now();
            let (ps, pl) = pjrt.predict(&arc, &sub.x)?;
            let pjrt_dt = t_pjrt.elapsed().as_secs_f64();
            let (ns, nl) = Engine::Native.predict(&arc, &sub.x)?;
            let mut disagreements = 0;
            for i in 0..ps.len() {
                let scale = ns[i].abs().max(1.0);
                assert!(
                    (ps[i] - ns[i]).abs() < 1e-3 * scale,
                    "score drift at {i}: pjrt {} vs native {}",
                    ps[i],
                    ns[i]
                );
                if pl[i] != nl[i] {
                    disagreements += 1; // only possible within f32 tol of a plane
                }
            }
            assert!(disagreements <= 2, "{disagreements} label disagreements");
            println!(
                "       [pjrt] scored 128 queries in {pjrt_dt:.4}s, \
                 max |Δscore| within f32 tolerance, {disagreements} boundary flips"
            );
        }

        println!(
            "{m:>6} {:>10.3} {:>8.3} {:>8} {:>10} {paper_t:>12.2} {paper_mcc:>12.2}",
            report.stats.seconds,
            cm.mcc(),
            model.n_sv(),
            report.stats.iterations,
        );
    }

    println!("\nall layers composed: train → certify → eval → persist → serve ✓");
    coordinator.shutdown();
    Ok(())
}
