//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the system on the paper's workload at every
//! Table-1 size: synthetic data → SMO training → independent KKT
//! certification → MCC evaluation → model persistence → serving through
//! the coordinator (PJRT engine when artifacts are present) → engine
//! equivalence check (native vs PJRT scores). Prints the Table-1 rows
//! with the paper's reported values alongside.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e
//! ```

use std::sync::Arc;
use std::time::Instant;

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::smo::{train_full, SmoParams};
use slabsvm::solver::validate::certify;

const PAPER: &[(usize, f64, f64)] = &[
    (500, 0.35, 0.07),
    (1000, 0.67, 0.13),
    (2000, 2.1, 0.26),
    (5000, 5.91, 0.33),
];

fn main() -> slabsvm::Result<()> {
    let pjrt = Engine::pjrt("artifacts").ok();
    println!(
        "end-to-end driver | engines: native{}",
        if pjrt.is_some() { " + pjrt" } else { " (pjrt unavailable)" }
    );
    let params = SmoParams::default(); // the paper's constants

    println!(
        "\n{:>6} {:>10} {:>8} {:>8} {:>10} {:>12} {:>12}",
        "m", "time(s)", "MCC", "SVs", "iters", "paper t(s)", "paper MCC"
    );

    let coordinator =
        Coordinator::start(Engine::Native, BatcherConfig::default(), 2);

    for &(m, paper_t, paper_mcc) in PAPER {
        let ds = SlabConfig::default().generate(m, 1000 + m as u64);

        // train (L3 solver over the native Gram)
        let (model, out) = train_full(&ds.x, Kernel::Linear, &params)?;

        // certify against an independently computed Gram matrix
        let k = Kernel::Linear.gram(&ds.x, 4);
        certify(
            &k,
            &out.alpha,
            &out.alpha_bar,
            out.rho1,
            out.rho2,
            params.nu1,
            params.nu2,
            params.eps,
            1e-2 * (1.0 + out.rho2.abs()),
        )
        .expect("solution must certify");

        // evaluate
        let eval = SlabConfig::default().generate_eval(m / 2, m / 2, 7 + m as u64);
        let cm = model.evaluate(&eval);

        // persist + reload
        let path = format!("/tmp/slabsvm_e2e_{m}.json");
        model.save(&path)?;
        let reloaded = slabsvm::solver::ocssvm::SlabModel::load(&path)?;

        // serve through the coordinator
        let name = format!("e2e-{m}");
        coordinator.register(&name, reloaded);
        let queries: Vec<Vec<f64>> =
            (0..eval.len().min(256)).map(|i| eval.x.row(i).to_vec()).collect();
        let resp = coordinator.score(&name, queries.clone())?;
        for (i, &label) in resp.labels.iter().enumerate() {
            assert_eq!(label, model.classify(eval.x.row(i)), "serving mismatch");
        }

        // engine equivalence: PJRT scores must match native (f32 tol)
        if let Some(pjrt) = &pjrt {
            let arc = Arc::new(model.clone());
            let sub = eval.select(&(0..128).collect::<Vec<_>>());
            let t_pjrt = Instant::now();
            let (ps, pl) = pjrt.predict(&arc, &sub.x)?;
            let pjrt_dt = t_pjrt.elapsed().as_secs_f64();
            let (ns, nl) = Engine::Native.predict(&arc, &sub.x)?;
            let mut disagreements = 0;
            for i in 0..ps.len() {
                let scale = ns[i].abs().max(1.0);
                assert!(
                    (ps[i] - ns[i]).abs() < 1e-3 * scale,
                    "score drift at {i}: pjrt {} vs native {}",
                    ps[i],
                    ns[i]
                );
                if pl[i] != nl[i] {
                    disagreements += 1; // only possible within f32 tol of a plane
                }
            }
            assert!(disagreements <= 2, "{disagreements} label disagreements");
            println!(
                "       [pjrt] scored 128 queries in {pjrt_dt:.4}s, \
                 max |Δscore| within f32 tolerance, {disagreements} boundary flips"
            );
        }

        println!(
            "{m:>6} {:>10.3} {:>8.3} {:>8} {:>10} {paper_t:>12.2} {paper_mcc:>12.2}",
            out.stats.seconds,
            cm.mcc(),
            model.n_sv(),
            out.stats.iterations,
        );
    }

    println!("\nall layers composed: train → certify → eval → persist → serve ✓");
    coordinator.shutdown();
    Ok(())
}
