//! Quickstart: train an OCSSVM with SMO, inspect it, classify points.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::smo::{train_full, SmoParams};

fn main() -> slabsvm::Result<()> {
    // 1. A one-class training set: 1000 points along a noisy 2-D band
    //    (the documented stand-in for the paper's toy dataset).
    let config = SlabConfig::default();
    let train = config.generate(1000, 42);
    println!("training points: {} (d = {})", train.len(), train.dim());

    // 2. Train with the paper's constants: nu1 = 0.5, nu2 = 0.01, eps = 2/3.
    let params = SmoParams { nu1: 0.5, nu2: 0.01, eps: 2.0 / 3.0, ..Default::default() };
    let (model, outcome) = train_full(&train.x, Kernel::Linear, &params)?;
    println!(
        "trained in {} SMO iterations ({:.3}s): {} support vectors",
        outcome.stats.iterations, outcome.stats.seconds, model.n_sv()
    );
    println!(
        "slab: rho1 = {:.4}, rho2 = {:.4} (width {:.4})",
        model.rho1,
        model.rho2,
        model.width()
    );

    // 3. Classify: +1 inside the slab (target class), -1 outside.
    let eval = config.generate_eval(500, 500, 7);
    let confusion = model.evaluate(&eval);
    println!(
        "eval on 500 positives + 500 anomalies: MCC = {:.3}, F1 = {:.3}, \
         accuracy = {:.3}",
        confusion.mcc(),
        confusion.f1(),
        confusion.accuracy()
    );

    // 4. Single-point queries.
    let inside = eval.x.row(0); // a positive sample
    println!(
        "point ({:.2}, {:.2}): label {:+}, margin {:.4}",
        inside[0],
        inside[1],
        model.classify(inside),
        model.margin(inside)
    );

    // 5. Persist + reload.
    model.save("/tmp/slabsvm_quickstart.json")?;
    let reloaded =
        slabsvm::solver::ocssvm::SlabModel::load("/tmp/slabsvm_quickstart.json")?;
    assert_eq!(reloaded.classify(inside), model.classify(inside));
    println!("model round-tripped through /tmp/slabsvm_quickstart.json");
    Ok(())
}
