//! Quickstart: train an OCSSVM through the unified `Trainer` API,
//! inspect the report, classify points.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::{SolverKind, Trainer};

fn main() -> slabsvm::Result<()> {
    // 1. A one-class training set: 1000 points along a noisy 2-D band
    //    (the documented stand-in for the paper's toy dataset).
    let config = SlabConfig::default();
    let train = config.generate(1000, 42);
    println!("training points: {} (d = {})", train.len(), train.dim());

    // 2. Train with the paper's constants: nu1 = 0.5, nu2 = 0.01, eps = 2/3.
    //    Every solver kind trains through the same `fit` — swap
    //    SolverKind::Smo for ::Pg / ::Ipm / ::OcsvmSmo and nothing else
    //    changes.
    let report = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .nu1(0.5)
        .nu2(0.01)
        .eps(2.0 / 3.0)
        .fit(&train.x)?;
    println!(
        "trained in {} SMO iterations ({:.3}s): {} support vectors",
        report.stats.iterations,
        report.stats.seconds,
        report.model.n_sv()
    );
    println!(
        "slab: rho1 = {:.4}, rho2 = {:.4} (width {:.4})",
        report.model.rho1,
        report.model.rho2,
        report.model.width()
    );
    // every fit carries its own KKT certificate — no separate call needed
    println!(
        "certificate: max KKT violation {:.3e}, |sum(alpha) - 1| = {:.1e}",
        report.certificate.max_kkt_violation,
        report.certificate.sum_alpha_violation
    );

    // 3. Classify: +1 inside the slab (target class), -1 outside.
    let eval = config.generate_eval(500, 500, 7);
    let confusion = report.model.evaluate(&eval);
    println!(
        "eval on 500 positives + 500 anomalies: MCC = {:.3}, F1 = {:.3}, \
         accuracy = {:.3}",
        confusion.mcc(),
        confusion.f1(),
        confusion.accuracy()
    );

    // 4. Single-point queries.
    let inside = eval.x.row(0); // a positive sample
    println!(
        "point ({:.2}, {:.2}): label {:+}, margin {:.4}",
        inside[0],
        inside[1],
        report.model.classify(inside),
        report.model.margin(inside)
    );

    // 5. Persist + reload.
    report.model.save("/tmp/slabsvm_quickstart.json")?;
    let reloaded =
        slabsvm::solver::ocssvm::SlabModel::load("/tmp/slabsvm_quickstart.json")?;
    assert_eq!(reloaded.classify(inside), report.model.classify(inside));
    println!("model round-tripped through /tmp/slabsvm_quickstart.json");

    // 6. Solver names round-trip for CLI/config use.
    let kind: SolverKind = "smo".parse()?;
    assert_eq!(kind, SolverKind::Smo);
    Ok(())
}
