//! Multi-tenant streaming: one coordinator, a fleet of drifting sensors.
//!
//! Six tenants stream readings concurrently through the sharded session
//! manager: producer threads enqueue onto bounded shard mailboxes, shard
//! workers absorb each tenant's samples in order (weighted-fair, so the
//! deliberately "hot" tenant below cannot starve its shard-mates), and
//! every absorbed reading hot-swaps that tenant's published model in the
//! registry — scoring traffic through the batcher keeps flowing the
//! whole time. One tenant's baseline sags mid-stream; its drift monitor
//! trips and a background cascade retrain lands on the owning shard
//! without pausing anyone else. Before the fleet closes, one tenant
//! handles a deletion request: `Coordinator::forget` routes the
//! removal to the owning shard, which withdraws the reading's dual
//! mass, repairs and re-publishes — no retrain, no pause.
//!
//! ```bash
//! cargo run --release --example multi_stream_serving
//! ```

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{Drift, DriftSchedule, SlabConfig, SlabStream};
use slabsvm::runtime::Engine;
use slabsvm::stream::{
    DriftConfig, StreamConfig, StreamPoolConfig, StreamSpec,
};

fn main() -> slabsvm::Result<()> {
    let fast = std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1");
    let per_tenant = if fast { 300 } else { 1200 };
    let tenants = 6usize;
    let hot = 0usize; // tenant-0 produces 4x the traffic
    let drifter = 1usize; // tenant-1's baseline sags mid-stream

    let cfg = StreamConfig {
        window: 128,
        min_train: 64,
        drift: DriftConfig {
            recent: 64,
            min_observations: 32,
            outside_frac: 0.85,
            rho_rel: 6.0,
        },
        ..Default::default()
    };

    let coordinator = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        2,
        StreamPoolConfig { shards: 2, mailbox_cap: 512, checkpoint: None },
    );
    coordinator.open_streams(
        (0..tenants)
            .map(|i| StreamSpec::new(format!("tenant-{i}"), cfg))
            .collect(),
    )?;
    println!(
        "{tenants} tenants on {} shards — tenant-{hot} runs 4x hot, \
         tenant-{drifter} drifts mid-stream",
        coordinator.stream_manager().shard_count()
    );

    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for i in 0..tenants {
            let c = &coordinator;
            scope.spawn(move || {
                let points =
                    if i == hot { per_tenant * 4 } else { per_tenant };
                let mut sensor =
                    SlabStream::new(SlabConfig::default(), 5100 + i as u64);
                if i == drifter {
                    sensor = sensor.with_drift(DriftSchedule {
                        drift: Drift::MeanShift { delta: -9.0 },
                        start: points / 2,
                        duration: 80,
                    });
                }
                let name = format!("tenant-{i}");
                for _ in 0..points {
                    let x = sensor.next_point();
                    if c.push(&name, &x).is_err() {
                        return;
                    }
                }
            });
        }
        // live scoring against whichever tenants are already warm
        let c = &coordinator;
        scope.spawn(move || {
            let mut probe = SlabStream::new(SlabConfig::default(), 99);
            let mut served = 0u64;
            while served < 200 {
                for i in 0..tenants {
                    let name = format!("tenant-{i}");
                    if c.model(&name).is_some() {
                        let x = probe.next_point();
                        if c.score(&name, vec![x.to_vec()]).is_ok() {
                            served += 1;
                        }
                    }
                }
                std::thread::yield_now();
            }
        });
    });
    coordinator.quiesce_streams();
    let dt = t0.elapsed().as_secs_f64();

    // a deletion request for tenant-2: its most recent reading's stable
    // id is its arrival count minus one (ids are 0-based push indices)
    let out = coordinator.forget("tenant-2", per_tenant as u64 - 1)?;
    println!(
        "tenant-2 forgot reading #{}: {} resident remain, model v{}",
        out.id,
        out.resident,
        out.version.unwrap_or(0)
    );

    let mut total_updates = 0u64;
    for i in 0..tenants {
        let s = coordinator.close_stream(&format!("tenant-{i}"))?;
        total_updates += s.updates;
        println!(
            "  {}: {} updates, {} retrains, model v{}, slab=[{:.2}, {:.2}]{}",
            s.name,
            s.updates,
            s.retrains,
            s.version.unwrap_or(0),
            s.rho.0,
            s.rho.1,
            if i == hot { "  <- hot" } else if i == drifter { "  <- drifted" } else { "" }
        );
    }
    println!(
        "\n{total_updates} absorbs across {tenants} tenants in {dt:.2}s \
         ({:.0} updates/s aggregate)",
        total_updates as f64 / dt
    );
    println!("streams: {}", coordinator.stats().stream_summary());
    println!("scoring: {}", coordinator.stats().summary());
    coordinator.shutdown();
    Ok(())
}
