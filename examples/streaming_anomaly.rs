//! Streaming anomaly detection: a slab model that never goes stale.
//!
//! A sensor emits an unbounded stream of readings. We keep a one-class
//! slab model current with the `stream` subsystem: every reading is
//! scored against the live model, absorbed by the incremental SMO
//! (evicting the oldest reading once the window is full), and the
//! refreshed model is hot-swapped into the coordinator's registry —
//! scoring traffic through the batcher never stops. Mid-stream the
//! sensor's baseline shifts (a mean-shift drift); the drift monitor
//! trips, a full cascade retrain runs in the background, and the new
//! model version starts serving while readings keep flowing. At the
//! end, one reading is **forgotten** — targeted unlearning by its
//! stable sample id withdraws its dual mass and repairs, so the
//! re-published model provably no longer reflects it (a "delete my
//! data" request at streaming cost, no retrain).
//!
//! ```bash
//! cargo run --release --example streaming_anomaly
//! ```

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{Drift, DriftSchedule, SlabConfig, SlabStream};
use slabsvm::runtime::Engine;
use slabsvm::stream::{DriftConfig, StreamConfig};

fn main() -> slabsvm::Result<()> {
    let fast = std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1");
    let total = if fast { 600 } else { 2400 };
    let shift_at = total / 2;

    // the sensor: a noisy band that sags to a lower baseline mid-stream
    let mut sensor = SlabStream::new(SlabConfig::default(), 2026).with_drift(
        DriftSchedule {
            drift: Drift::MeanShift { delta: -9.0 },
            start: shift_at,
            duration: 100,
        },
    );

    let coordinator =
        Coordinator::start(Engine::Native, BatcherConfig::default(), 2);
    let mut session = coordinator.open_stream(
        "sensor",
        StreamConfig {
            window: 256,
            min_train: 96,
            drift: DriftConfig {
                recent: 96,
                min_observations: 48,
                outside_frac: 0.85,
                rho_rel: 4.0,
            },
            ..Default::default()
        },
    );

    println!("streaming {total} readings (baseline shift at {shift_at})…");
    let t0 = std::time::Instant::now();
    let mut anomalies = 0u64;
    let mut last_version = 0u64;
    for i in 0..total {
        let reading = sensor.next_point();
        // score through the serving path before absorbing — exactly what
        // live traffic sees (skipped during model warmup)
        if last_version > 0 {
            let resp = coordinator.score("sensor", vec![reading.to_vec()])?;
            if resp.labels[0] < 0 {
                anomalies += 1;
            }
        }
        let update = coordinator.stream_push(&mut session, &reading)?;
        if let Some(v) = update.version {
            last_version = v;
        }
        if let Some(id) = update.retrain_submitted {
            println!(
                "[{i}] drift detected ({:?}) → background retrain {id:?} \
                 (scoring continues)",
                update.drift
            );
        }
        if let Some(v) = update.retrain_completed {
            println!("[{i}] retrain landed: serving model v{v}");
        }
        if (i + 1) % (total / 6) == 0 {
            let (r1, r2) = session.solver().rho();
            println!(
                "[{}] model v{last_version}  slab=[{r1:.2}, {r2:.2}]  \
                 outside={:.2}",
                i + 1,
                session.drift_monitor().outside_fraction()
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\n{total} readings in {dt:.2}s ({:.0} updates/s) — {anomalies} \
         flagged anomalous, {} background retrains, final model v{last_version}",
        total as f64 / dt,
        session.retrains()
    );

    // Targeted unlearning: the sensor's owner asks us to delete one
    // specific reading. Its stable id is its arrival index; forgetting
    // it withdraws its dual mass, repairs KKT and hands back a model
    // fit on the remaining window — which we hot-swap so the served
    // slab stops reflecting the deleted reading immediately.
    let forget_id = session.solver().window().id(0);
    let before = session.solver().len();
    let forgotten = session.forget(forget_id)?;
    if let Some(model) = forgotten.model {
        coordinator.register("sensor", model);
    }
    println!(
        "forgot reading #{forget_id}: window {before} -> {} resident, \
         repaired in {} pair updates",
        forgotten.resident,
        session.solver().last_stats().iterations
    );

    println!("coordinator: {}", coordinator.stats().summary());
    coordinator.shutdown();
    Ok(())
}
