//! Anomaly detection: sensor-drift monitoring with a slab of normality.
//!
//! Scenario modeled on the OCSSVM literature's gas-turbine use case
//! (paper refs [14][17]): a machine emits an 8-dimensional sensor vector
//! whose healthy distribution is a tight operating band; faults appear
//! as either *drops* (sensor degradation — below the band) or *spikes*
//! (overload — above the band). A single-plane OCSVM must cut away one
//! side only; the slab bounds normality from BOTH sides, which is the
//! OCSSVM's reason to exist. This example measures that difference —
//! both models trained through the one `Trainer` API, only the
//! `SolverKind` differs.
//!
//! ```bash
//! cargo run --release --example anomaly_detection
//! ```

use slabsvm::data::synthetic::gaussian_blob;
use slabsvm::data::Dataset;
use slabsvm::kernel::Kernel;
use slabsvm::linalg::Matrix;
use slabsvm::metrics::Confusion;
use slabsvm::solver::{SolverKind, Trainer};
use slabsvm::util::rng::Rng;

const DIM: usize = 8;

/// Healthy operating point: every sensor near its setpoint.
fn healthy(n: usize, rng: &mut Rng) -> Matrix {
    let center = [20.0, 18.0, 22.0, 19.5, 21.0, 20.5, 19.0, 20.0];
    gaussian_blob(&center[..DIM], 0.4, n, rng)
}

/// Fault modes: a uniform scale applied to the whole sensor vector —
/// drops (x0.7) and spikes (x1.3), i.e. radially below/above the band.
fn faulty(n: usize, rng: &mut Rng) -> Matrix {
    let mut out = Matrix::zeros(n, DIM);
    for i in 0..n {
        let h = healthy(1, rng);
        let scale = if rng.uniform() < 0.5 {
            rng.uniform_range(0.55, 0.85) // degradation
        } else {
            rng.uniform_range(1.15, 1.45) // overload
        };
        for j in 0..DIM {
            out.set(i, j, h.get(0, j) * scale);
        }
    }
    out
}

fn main() -> slabsvm::Result<()> {
    let mut rng = Rng::new(2024);
    let train_x = healthy(1200, &mut rng);

    // eval: healthy (+1) + both fault modes (-1)
    let eval_pos = healthy(400, &mut rng);
    let eval_neg = faulty(400, &mut rng);
    let mut y = vec![1i8; 400];
    y.extend(vec![-1i8; 400]);
    let eval = Dataset::new(eval_pos.vstack(&eval_neg), y);

    // --- OCSSVM (slab) -----------------------------------------------------
    let slab = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .nu1(0.1)
        .nu2(0.05)
        .eps(0.5)
        .fit(&train_x)?;
    let slab_cm = slab.model.evaluate(&eval);
    println!(
        "OCSSVM slab : {} iters, {} SVs, rho=[{:.2}, {:.2}]",
        slab.stats.iterations,
        slab.model.n_sv(),
        slab.model.rho1,
        slab.model.rho2
    );
    report("OCSSVM", &slab_cm);

    // --- OCSVM baseline (single plane, ref [2]) -----------------------------
    // same Trainer surface: the OCSVM kind returns a slab with no upper
    // plane (rho2 = NO_UPPER_PLANE), i.e. the classic sgn(s - rho).
    let ocsvm = Trainer::new(SolverKind::OcsvmSmo)
        .kernel(Kernel::Linear)
        .nu1(0.1)
        .fit(&train_x)?;
    let ocsvm_cm = ocsvm.model.evaluate(&eval);
    report("OCSVM ", &ocsvm_cm);

    // The slab must catch the overload faults the single plane lets
    // through: spikes sit on the "accept" side of the one-class SVM.
    println!(
        "\nslab advantage on two-sided faults: MCC {:.3} vs {:.3}",
        slab_cm.mcc(),
        ocsvm_cm.mcc()
    );
    assert!(
        slab_cm.mcc() > ocsvm_cm.mcc(),
        "the slab should beat the single plane on two-sided anomalies"
    );
    Ok(())
}

fn report(name: &str, c: &Confusion) {
    println!(
        "{name}: tp={:4} tn={:4} fp={:4} fn={:4}  MCC={:.3} F1={:.3} recall={:.3}",
        c.tp,
        c.tn,
        c.fp,
        c.fn_,
        c.mcc(),
        c.f1(),
        c.recall()
    );
}
