#!/usr/bin/env python3
"""Reference mirror of slablint for toolchain-less environments.

This script re-implements the lexer and the five rules of the Rust
binary (tools/slablint/src/) line for line, so the scan can be run —
and the committed allowlist validated — on a machine without cargo.
CI runs the Rust binary; this mirror exists so a contributor (or a
container without the toolchain) can still answer "would slablint
pass?" with `python3 tools/slablint/selfcheck.py`.

Keep the two in sync: any rule change lands in src/rules.rs AND here.
"""

import os
import re
import sys

# ------------------------------------------------------------- lexer

IDENT = re.compile(r"[A-Za-z0-9_]")


def is_ident(c):
    return bool(IDENT.match(c))


def strip(source):
    """Blank comments and literal contents, preserving line structure."""
    b = source
    n = len(b)
    out = []
    state = "code"
    depth = 0  # block-comment nesting / raw-string hashes
    i = 0
    while i < n:
        c = b[i]
        nxt = b[i + 1] if i + 1 < n else ""
        if state == "code":
            if c == "/" and nxt == "/":
                state = "line"
                out.append("  ")
                i += 2
            elif c == "/" and nxt == "*":
                state = "block"
                depth = 1
                out.append("  ")
                i += 2
            elif c == '"':
                state = "str"
                out.append('"')
                i += 1
            elif c in "rb" and _is_raw_start(b, i):
                j = i + 1
                if c == "b" and j < n and b[j] == "r":
                    j += 1
                hashes = 0
                while j < n and b[j] == "#":
                    hashes += 1
                    j += 1
                if j < n and b[j] == '"':
                    out.append(" " * (j - i) + '"')
                    if c == "b" and b[i + 1] != "r" and hashes == 0:
                        state = "str"
                    else:
                        state = "raw"
                        depth = hashes
                    i = j + 1
                else:
                    out.append(c)
                    i += 1
            elif c == "'" and _is_char_literal(b, i):
                state = "char"
                out.append("'")
                i += 1
            else:
                out.append(c)
                i += 1
        elif state == "line":
            if c == "\n":
                state = "code"
                out.append("\n")
            else:
                out.append(" ")
            i += 1
        elif state == "block":
            if c == "/" and nxt == "*":
                depth += 1
                out.append("  ")
                i += 2
            elif c == "*" and nxt == "/":
                depth -= 1
                state = "code" if depth == 0 else "block"
                out.append("  ")
                i += 2
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        elif state == "str":
            if c == "\\":
                out.append(" " + ("\n" if nxt == "\n" else " "))
                i += 2
            elif c == '"':
                state = "code"
                out.append('"')
                i += 1
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        elif state == "raw":
            if c == '"' and b[i + 1 : i + 1 + depth] == "#" * depth:
                out.append('"' + " " * depth)
                state = "code"
                i += 1 + depth
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        elif state == "char":
            if c == "\\":
                out.append("  ")
                i += 2
            elif c == "'":
                state = "code"
                out.append("'")
                i += 1
            else:
                out.append(" ")
                i += 1
    return "".join(out).split("\n")


def _is_raw_start(b, i):
    if i > 0 and is_ident(b[i - 1]):
        return False
    j = i + 1
    n = len(b)
    if b[i] == "b":
        if j < n and b[j] == "'":
            return False
        if j < n and b[j] == "r":
            j += 1
        elif j >= n or b[j] not in '"#':
            return False
    while j < n and b[j] == "#":
        j += 1
    return j < n and b[j] == '"'


def _is_char_literal(b, i):
    if i + 1 >= len(b):
        return False
    c1 = b[i + 1]
    if c1 == "\\":
        return True
    if is_ident(c1):
        return i + 2 < len(b) and b[i + 2] == "'"
    return True


def test_mod_lines(lines):
    n = len(lines)
    in_test = [False] * n
    i = 0
    while i < n:
        if lines[i].lstrip().startswith("#[cfg(test)]"):
            j = i + 1
            while j < n and (
                not lines[j].strip() or lines[j].lstrip().startswith("#[")
            ):
                j += 1
            if j < n and lines[j].lstrip().startswith("mod "):
                depth = 0
                started = False
                k = j
                while k < n:
                    for c in lines[k]:
                        if c == "{":
                            depth += 1
                            started = True
                        elif c == "}":
                            depth -= 1
                    in_test[k] = True
                    if started and depth <= 0:
                        break
                    k += 1
                in_test[i] = True
                i = k + 1
                continue
        i += 1
    return in_test


class Stripped:
    def __init__(self, source):
        self.lines = strip(source)
        self.in_test = test_mod_lines(self.lines)
        # raw lines: findings report these, and the allowlist matches
        # against them (patterns may cite string contents)
        self.raw = source.split("\n")


# ------------------------------------------------------------- rules

R1_SCOPE = [
    "stream/shard.rs",
    "stream/manager.rs",
    "stream/persist.rs",
    "coordinator/jobs.rs",
    "serve/http.rs",
    "serve/auth.rs",
    "serve/limits.rs",
    "serve/router.rs",
    "serve/server.rs",
    "kernel/featmap.rs",
    "solver/approx.rs",
    "stream/approx.rs",
]
R1_TOKENS = [".unwrap()", ".expect(", "panic!(", "unreachable!(", ".unwrap_unchecked("]
SUBSCRIPT_KEYWORDS = {
    "mut", "ref", "dyn", "in", "as", "return", "else",
    "match", "if", "move", "impl", "where", "let",
}


def finding(rule, file, idx, msg, s):
    return {
        "rule": rule,
        "file": file,
        "line": idx + 1,
        "message": msg,
        "text": s.raw[idx].strip() if idx < len(s.raw) else "",
    }


def variable_subscripts(line):
    out = []
    i = 0
    n = len(line)
    while i < n:
        if line[i] == "[":
            k = i
            while k > 0 and line[k - 1].isspace():
                k -= 1
            prev = line[k - 1] if k > 0 else ""
            w = k
            while w > 0 and is_ident(line[w - 1]):
                w -= 1
            word = line[w:k]
            keyword = word in SUBSCRIPT_KEYWORDS
            lifetime = w > 0 and line[w - 1] == "'"
            is_index = (not keyword) and (not lifetime) and (
                bool(prev) and (is_ident(prev) or prev in ")]")
            )
            if is_index:
                depth = 1
                j = i + 1
                while j < n and depth > 0:
                    if line[j] == "[":
                        depth += 1
                    elif line[j] == "]":
                        depth -= 1
                    j += 1
                if depth == 0:
                    idx = line[i + 1 : j - 1]
                    literal = bool(idx) and all(
                        c.isdigit() or c in "._" or c.isspace() for c in idx
                    )
                    if not literal and idx.strip():
                        out.append(idx.strip())
                    i = j
                    continue
        i += 1
    return out


def r1(file, s):
    out = []
    if not any(file.endswith(sc) for sc in R1_SCOPE):
        return out
    for i, line in enumerate(s.lines):
        if s.in_test[i]:
            continue
        for tok in R1_TOKENS:
            if tok in line:
                out.append(finding(
                    "R1", file, i,
                    f"panic path `{tok}` in availability-critical file",
                    s))
        for idx in variable_subscripts(line):
            out.append(finding(
                "R1", file, i,
                f"variable-index subscript `[{idx}]` can panic; use .get()",
                s))
    return out


R2_SCOPE = ["src/stream/", "src/coordinator/"]
R2_BARRIERS = [
    ".absorb(", "absorb_one(", ".repair(", "repair_in_place(",
    ".send(", ".recv()", ".submit(", ".fit(", ".join()",
    "write_atomic(", ".adopt(", "snapshot_all(",
]


def guard_binding(stmt):
    ends = [".lock();", ".read();", ".write();"]
    acquire = any(
        stmt.endswith(t) or stmt.endswith(t[:-1] + ".unwrap();") for t in ends
    )
    if not acquire:
        return None
    if not stmt.startswith("let "):
        return None
    rest = stmt[4:]
    if rest.startswith("mut "):
        rest = rest[4:]
    name = ""
    for c in rest:
        if is_ident(c):
            name += c
        else:
            break
    if not name or name == "_":
        return None
    return name


def r2(file, s):
    out = []
    if not any(d in file for d in R2_SCOPE) or "src/sync/" in file:
        return out
    depth = 0
    guards = []  # (name, depth at binding)
    pending = ""
    for i, line in enumerate(s.lines):
        if s.in_test[i]:
            continue
        if guards:
            for tok in R2_BARRIERS:
                if tok in line:
                    held = ", ".join(n for n, _ in guards)
                    out.append(finding(
                        "R2", file, i,
                        f"barrier `{tok}` while lock guard(s) [{held}] are live",
                        s))
        for c in line:
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
                guards = [(n, d) for n, d in guards if d <= depth]
        guards = [
            (n, d) for n, d in guards
            if f"drop({n})" not in line
        ]
        t = line.strip()
        if not pending and t.startswith("let "):
            pending = t
        elif pending:
            pending += " " + t
        if pending:
            if pending.endswith(";"):
                name = guard_binding(pending)
                if name:
                    guards.append((name, depth))
                pending = ""
            elif "{" in pending:
                pending = ""
    return out


R3_ALLOC = [
    "Vec::new(", "Vec::with_capacity(", "vec![", ".to_vec(", ".clone(",
    ".collect()", ".collect::<", "String::new(", "format!(", ".to_string(", "Box::new(",
]
R3_CONFIGS = [
    {
        "suffix": "stream/incremental.rs",
        "hot": ["bump_alpha", "bump_abar", "distribute", "collect", "seed",
                "replace_slot", "grow_add", "margin_of_slot",
                "recompute_margins", "repair", "score"],
        "warm": ["push", "forget", "forget_many"],
    },
    {
        "suffix": "solver/smo.rs",
        "hot": ["select_partner_second_order", "select_partner"],
        "warm": ["solve_from"],
    },
    {
        "suffix": "kernel/featmap.rs",
        "hot": ["fourier_into", "fourier_dot", "landmark_into",
                "landmark_dot"],
        "warm": [],
    },
    {
        "suffix": "solver/approx.rs",
        "hot": ["push_grown", "replace_row", "margin_of",
                "pair_step_alpha", "pair_step_abar"],
        "warm": ["repair", "remove_row", "batch_init"],
    },
    {
        "suffix": "stream/approx.rs",
        "hot": ["score"],
        "warm": ["push", "forget", "forget_many"],
    },
]


def fn_body(s, name):
    pat = f"fn {name}"
    for i, line in enumerate(s.lines):
        if s.in_test[i]:
            continue
        p = line.find(pat)
        if p < 0:
            continue
        after = line[p + len(pat): p + len(pat) + 1]
        if after not in ("(", "<"):
            continue
        depth = 0
        started = False
        j = i
        while j < len(s.lines):
            for c in s.lines[j]:
                if c == "{":
                    depth += 1
                    started = True
                elif c == "}":
                    depth -= 1
            if started and depth <= 0:
                return (i, j)
            j += 1
        return None
    return None


def allocs_in_loops(body):
    out = []
    stack = []
    pending_loop = False
    for i, line in enumerate(body):
        header_ok = "impl " not in line
        word = ""
        for c in line + "\n":
            if is_ident(c):
                word += c
                continue
            if header_ok and word in ("for", "while", "loop"):
                pending_loop = True
            word = ""
            if c == "{":
                stack.append(pending_loop)
                pending_loop = False
            elif c == "}":
                if stack:
                    stack.pop()
            elif c == ";":
                pending_loop = False
        if any(stack):
            for tok in R3_ALLOC:
                if tok in line:
                    out.append((i, tok))
    return out


def r3(file, s):
    out = []
    cfg = next((c for c in R3_CONFIGS if file.endswith(c["suffix"])), None)
    if cfg is None:
        return out

    def missing(name):
        return {
            "rule": "R3", "file": file, "line": 1,
            "message": (f"configured fn `{name}` not found — update "
                        "R3_CONFIGS (silently skipping it would disable "
                        "the rule)"),
            "text": "",
        }

    for name in cfg["hot"]:
        span = fn_body(s, name)
        if span is None:
            out.append(missing(name))
            continue
        start, end = span
        for i in range(start, end + 1):
            for tok in R3_ALLOC:
                if tok in s.lines[i]:
                    out.append(finding(
                        "R3", file, i,
                        f"allocation `{tok}` in hot fn `{name}`", s))
    for name in cfg["warm"]:
        span = fn_body(s, name)
        if span is None:
            out.append(missing(name))
            continue
        start, end = span
        for i, tok in allocs_in_loops(s.lines[start:end + 1]):
            out.append(finding(
                "R3", file, start + i,
                f"allocation `{tok}` inside a loop of warm fn `{name}`",
                s))
    return out


def service_stats_fields(s):
    out = []
    start = next((i for i, l in enumerate(s.lines)
                  if "pub struct ServiceStats" in l), None)
    if start is None:
        return out
    depth = 0
    started = False
    for i in range(start, len(s.lines)):
        line = s.lines[i]
        if started and depth > 0:
            t = line.strip()
            if t.startswith("pub "):
                rest = t[4:]
                colon = rest.find(":")
                if colon > 0:
                    name = rest[:colon].strip()
                    if name and all(is_ident(c) for c in name):
                        out.append((name, i))
        for c in line:
            if c == "{":
                depth += 1
                started = True
            elif c == "}":
                depth -= 1
        if started and depth <= 0:
            break
    return out


def r4(stats_file, stats, sources, surface_extra):
    out = []
    fields = service_stats_fields(stats)
    surface = ""
    for name in ("summary", "stream_summary"):
        span = fn_body(stats, name)
        if span:
            surface += "\n".join(stats.lines[span[0]:span[1] + 1]) + "\n"
    surface += surface_extra
    for field, line_idx in fields:
        inc_pats = [f".{field}.inc(", f".{field}.add(", f".{field}.record"]
        incremented = any(
            any(p in l for p in inc_pats)
            for _, s in sources
            for i, l in enumerate(s.lines)
            if not s.in_test[i]
        )
        if not incremented:
            out.append(finding(
                "R4", stats_file, line_idx,
                f"ServiceStats field `{field}` is never incremented",
                stats))
        shown = f"self.{field}" in surface or f".{field}." in surface
        if not shown:
            out.append(finding(
                "R4", stats_file, line_idx,
                f"ServiceStats field `{field}` is not surfaced by "
                "summary()/stream_summary()/CLI",
                stats))
    return out


def r4_export(export_file, export, stats):
    out = []
    span = fn_body(export, "registry")
    if span is None:
        out.append({"rule": "R4", "file": export_file, "line": 1,
                    "message": ("fn registry(…) not found — metric export "
                                "check cannot run"),
                    "text": ""})
        return out
    start, end = span
    body = export.lines[start:end + 1]
    # (a) every stats field reaches the registry builder
    for field, _ in service_stats_fields(stats):
        pat = "." + field
        exported = False
        for l in body:
            p = l.find(pat)
            while p >= 0:
                nxt = l[p + len(pat): p + len(pat) + 1]
                if not nxt or not is_ident(nxt):
                    exported = True
                    break
                p = l.find(pat, p + 1)
            if exported:
                break
        if not exported:
            out.append(finding(
                "R4", export_file, start,
                f"ServiceStats field `{field}` is not exported by the obs "
                "metric registry",
                export))
    # (b) registered names: unique, slabsvm_-prefixed identifiers.
    # Stripped blanks literal contents in place, so a `"` pair in a
    # stripped line brackets the same columns of the raw line; a
    # bare-identifier string in the builder is a metric name (help
    # strings always contain spaces).
    names = []
    for i in range(start, end + 1):
        sl = export.lines[i]
        rl = export.raw[i] if i < len(export.raw) else ""
        j = 0
        while j < len(sl):
            if sl[j] != '"':
                j += 1
                continue
            k = sl.find('"', j + 1)
            if k < 0:
                break
            lit = rl[j + 1:k]
            if lit and all(is_ident(c) for c in lit):
                names.append((lit, i))
            j = k + 1
    seen = set()
    for name, i in names:
        if not name.startswith("slabsvm_"):
            out.append(finding(
                "R4", export_file, i,
                f"metric name `{name}` is not `slabsvm_`-prefixed", export))
        if name in seen:
            out.append(finding(
                "R4", export_file, i,
                f"metric name `{name}` registered more than once", export))
        seen.add(name)
    # (c) both exposition formats exist to render the registry
    for fname in ("prometheus_text", "json_lines"):
        if fn_body(export, fname) is None:
            out.append({"rule": "R4", "file": export_file, "line": 1,
                        "message": (f"exporter fn `{fname}` missing from "
                                    "the export layer"),
                        "text": ""})
    return out


# ------------------------------------------------------ clippy sweep
#
# C1: a pattern-level stand-in for the three clippy lints the project
# cares most about on the numeric hot paths, runnable where `cargo
# clippy` cannot be (this container has no Rust toolchain). Selfcheck-
# only by design — CI runs real clippy; this sweep exists so a
# toolchain-less environment still catches the common regressions.
# Non-test code only, like the rest of the rules.

RANGE_LOOP = re.compile(
    r"\bfor\s+([A-Za-z_][A-Za-z0-9_]*)\s+in\s+0\s*\.\.\s*"
    r"([A-Za-z_][A-Za-z0-9_\.]*)\s*\.len\(\)")
# like clippy's float_cmp, exact comparison against literal ZERO is
# allowed (checking for an exact sentinel/untouched value is idiomatic)
FLOAT_CMP = re.compile(
    r"(\d+\.\d*(?:[eE][+-]?\d+)?)\s*(?:==|!=)"
    r"|(?:==|!=)\s*-?(\d+\.\d*(?:[eE][+-]?\d+)?)")


def float_cmp_hits(line):
    return [m for m in FLOAT_CMP.finditer(line)
            if float(m.group(1) or m.group(2)) != 0.0]


def loop_body_span(lines, start):
    depth = 0
    started = False
    for j in range(start, len(lines)):
        for c in lines[j]:
            if c == "{":
                depth += 1
                started = True
            elif c == "}":
                depth -= 1
        if started and depth <= 0:
            return (start, j)
    return None


def clippy_sweep(file, s):
    out = []
    for i, line in enumerate(s.lines):
        if s.in_test[i]:
            continue
        m = RANGE_LOOP.search(line)
        if m:
            var, coll = m.group(1), m.group(2)
            span = loop_body_span(s.lines, i)
            if span:
                # drop the loop header itself so its `var` binding does
                # not count as a non-indexing use
                body = "\n".join(s.lines[span[0]:span[1] + 1])
                body = body.replace(m.group(0), "", 1)
                indexed = re.compile(
                    rf"{re.escape(coll)}\s*\[\s*{var}\s*\]")
                memcpy = re.compile(
                    rf"[\w\.\(\)]+\s*\[\s*{var}\s*\]\s*=\s*"
                    rf"[\w\.\(\)]+\s*\[\s*{var}\s*\]\s*;")
                if memcpy.search(body):
                    out.append(finding(
                        "C1", file, i,
                        "manual_memcpy: element-by-element copy loop — "
                        "use copy_from_slice/clone_from_slice", s))
                elif not re.search(
                        rf"\b{var}\b", indexed.sub("", body)):
                    out.append(finding(
                        "C1", file, i,
                        f"needless_range_loop: `{var}` only indexes "
                        f"`{coll}` — iterate it (or use .iter().enumerate())",
                        s))
        if float_cmp_hits(line):
            out.append(finding(
                "C1", file, i,
                "float_cmp: `==`/`!=` against a nonzero float literal in "
                "non-test code — compare with a tolerance or use to_bits()",
                s))
    return out


BRACKET = re.compile(r"\[\[([A-Za-z0-9_-]+)\]\]")
SECTION = re.compile(r"§([A-Za-z0-9.]+)")


def design_headings(design):
    out = []
    for line in design.split("\n"):
        t = line.lstrip()
        if t.startswith("### "):
            rest = t[4:]
        elif t.startswith("## "):
            rest = t[3:]
        else:
            continue
        first = rest.split()[0] if rest.split() else ""
        out.append(first.rstrip("."))
    return out


def design_definitions(design):
    out = []
    for line in design.split("\n"):
        t = line.lstrip().lstrip("*- ")
        m = BRACKET.match(t)
        if m:
            out.append(m.group(1))
    return out


def heading_matches(heading, ref):
    return heading == ref or heading.startswith(ref + ".")


def r5(design, rs_sources):
    out = []
    headings = design_headings(design)
    defs = design_definitions(design)

    def check_line(file, idx, line, comment_only):
        scan = line
        if comment_only:
            p = line.find("//")
            if p < 0:
                return
            scan = line[p:]
        if "DESIGN" in scan:
            for m in SECTION.finditer(scan):
                ref = m.group(1).rstrip(".")
                if ref and not any(heading_matches(h, ref) for h in headings):
                    out.append({
                        "rule": "R5", "file": file, "line": idx + 1,
                        "message": f"§{ref} does not match any DESIGN.md heading",
                        "text": line.strip(),
                    })
        for m in BRACKET.finditer(scan):
            sym = m.group(1)
            is_def = (not comment_only) and scan.lstrip().startswith(f"[[{sym}]]")
            if not is_def and sym not in defs:
                out.append({
                    "rule": "R5", "file": file, "line": idx + 1,
                    "message": f"[[{sym}]] has no definition line in DESIGN.md",
                    "text": line.strip(),
                })

    for i, line in enumerate(design.split("\n")):
        check_line("DESIGN.md", i, line, False)
    for path, src in rs_sources:
        for i, line in enumerate(src.split("\n")):
            check_line(path, i, line, True)
    return out


# --------------------------------------------------------- allowlist

def parse_allow(text):
    out = []
    for i, raw in enumerate(text.split("\n")):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = [p.strip() for p in line.split("|", 3)]
        if len(parts) != 4 or any(not p for p in parts):
            raise SystemExit(
                f"slablint.allow:{i + 1}: want "
                f"`RULE | file | pattern | justification`, got `{line}`")
        out.append({
            "rule": parts[0], "file": parts[1], "pattern": parts[2],
            "justification": parts[3], "line": i + 1,
        })
    return out


def apply_allow(findings, entries):
    used = [False] * len(entries)
    open_findings = []
    for f in findings:
        hit = next(
            (k for k, e in enumerate(entries)
             if e["rule"] == f["rule"] and f["file"].endswith(e["file"])
             and e["pattern"] in f["text"]),
            None)
        if hit is None:
            open_findings.append(f)
        else:
            used[hit] = True
    stale = [k for k, u in enumerate(used) if not u]
    return open_findings, stale



# ------------------------------------------------ fixture assertions

DESIGN_FIXTURE = """\
## 1. System inventory

### 1.1 Errata

[[R1]] Panic-freedom on availability-critical paths.
"""


def run_fixtures():
    """Mirror of tools/slablint/tests/rules.rs — same fixtures, same
    expected counts, runnable without cargo."""
    fdir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "tests", "fixtures")

    def load(name):
        with open(os.path.join(fdir, name), encoding="utf-8") as fh:
            return fh.read()

    failures = []

    def check(label, got, want):
        if got != want:
            failures.append(f"{label}: want {want} finding(s), got {got}")

    f = r1("rust/src/stream/shard.rs", Stripped(load("r1_bad.rs")))
    check("r1_bad", len(f), 4)
    f = r1("rust/src/stream/shard.rs", Stripped(load("r1_ok.rs")))
    check("r1_ok", len(f), 0)
    f = r1("rust/src/solver/smo.rs", Stripped(load("r1_bad.rs")))
    check("r1 out-of-scope", len(f), 0)

    f = r2("rust/src/stream/fixture.rs", Stripped(load("r2_bad.rs")))
    check("r2_bad", len(f), 3)
    f = r2("rust/src/stream/fixture.rs", Stripped(load("r2_ok.rs")))
    check("r2_ok", len(f), 0)

    f = r3("rust/src/stream/incremental.rs", Stripped(load("r3_bad.rs")))
    check("r3_bad", len(f), 3)
    f = r3("rust/src/stream/incremental.rs", Stripped(load("r3_ok.rs")))
    check("r3_ok", len(f), 0)
    f = r3("rust/src/stream/incremental.rs", Stripped("fn unrelated() {}\n"))
    if not any("not found" in x["message"] for x in f):
        failures.append("r3 config drift not reported")

    src4 = load("r4_bad.rs")
    f = r4("r4_bad.rs", Stripped(src4), [("r4_bad.rs", Stripped(src4))], "")
    check("r4_bad", len(f), 3)
    src4 = load("r4_ok.rs")
    f = r4("r4_ok.rs", Stripped(src4), [("r4_ok.rs", Stripped(src4))], "")
    check("r4_ok", len(f), 0)

    f = r4_export("r4_export_bad.rs", Stripped(load("r4_export_bad.rs")),
                  Stripped(load("r4_bad.rs")))
    check("r4_export_bad", len(f), 4)
    f = r4_export("r4_export_ok.rs", Stripped(load("r4_export_ok.rs")),
                  Stripped(load("r4_ok.rs")))
    check("r4_export_ok", len(f), 0)

    f = r5(DESIGN_FIXTURE, [("r5_bad.rs", load("r5_bad.rs"))])
    check("r5_bad", len(f), 2)
    f = r5(DESIGN_FIXTURE, [("r5_ok.rs", load("r5_ok.rs"))])
    check("r5_ok", len(f), 0)

    # C1 clippy sweep (selfcheck-only — no .rs fixture file on purpose:
    # the Rust binary does not mirror this rule, real clippy does)
    c1src = (
        "fn f(dst: &mut [f64], src: &[f64], xs: &[f64]) -> f64 {\n"
        "    for i in 0..dst.len() { dst[i] = src[i]; }\n"
        "    let mut t = 0.0;\n"
        "    for i in 0..xs.len() { t += xs[i]; }\n"
        "    if t != 0.5 { t = 0.0; }\n"
        "    if t == 0.0 { t = 1.0; }\n"
        "    t\n"
        "}\n"
    )
    f = clippy_sweep("c1.rs", Stripped(c1src))
    check("c1 sweep (memcpy + range loop + nonzero float, zero allowed)",
          len(f), 3)
    c1ok = (
        "fn f(dst: &mut [f64], src: &[f64], xs: &[f64]) -> f64 {\n"
        "    dst.copy_from_slice(src);\n"
        "    let mut t: f64 = xs.iter().sum();\n"
        "    for i in 0..xs.len() { t += xs[i] * dst[i]; }\n"
        "    if (t - 0.5).abs() < 1e-9 { t = 0.0; }\n"
        "    t\n"
        "}\n"
    )
    f = clippy_sweep("c1.rs", Stripped(c1ok))
    check("c1 sweep clean (two-collection index loop allowed)", len(f), 0)

    for msg in failures:
        print(f"FIXTURE {msg}")
    print(f"slablint(selfcheck): {len(failures)} fixture failure(s)")
    return 0 if not failures else 1

# -------------------------------------------------------------- main

def main():
    if "--fixtures" in sys.argv:
        return run_fixtures()
    root = sys.argv[sys.argv.index("--root") + 1] if "--root" in sys.argv else None
    if root is None:
        d = os.path.abspath(os.path.dirname(__file__))
        while d != "/":
            if (os.path.isfile(os.path.join(d, "DESIGN.md"))
                    and os.path.isdir(os.path.join(d, "rust/src"))):
                root = d
                break
            d = os.path.dirname(d)
    if root is None:
        print("selfcheck: cannot locate repo root", file=sys.stderr)
        return 2

    files = []
    for dirpath, _, names in os.walk(os.path.join(root, "rust/src")):
        for n in names:
            if n.endswith(".rs"):
                files.append(os.path.join(dirpath, n))
    files.sort()

    sources = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            raw = fh.read()
        sources.append((rel, raw, Stripped(raw)))
    with open(os.path.join(root, "DESIGN.md"), encoding="utf-8") as fh:
        design = fh.read()

    findings = []
    for rel, _, s in sources:
        findings += r1(rel, s)
        findings += r2(rel, s)
        findings += r3(rel, s)
        findings += clippy_sweep(rel, s)
    stats_entry = next(
        ((rel, s) for rel, _, s in sources
         if rel.endswith("coordinator/stats.rs")), None)
    if stats_entry:
        surface_extra = next(
            ("\n".join(s.lines) for rel, _, s in sources
             if rel.endswith("src/main.rs")), "")
        pairs = [(rel, s) for rel, _, s in sources]
        findings += r4(stats_entry[0], stats_entry[1], pairs, surface_extra)
        export_entry = next(
            ((rel, s) for rel, _, s in sources
             if rel.endswith("obs/export.rs")), None)
        if export_entry:
            findings += r4_export(export_entry[0], export_entry[1],
                                  stats_entry[1])
        else:
            findings.append({"rule": "R4", "file": "rust/src/obs/export.rs",
                             "line": 1,
                             "message": ("obs/export.rs not found — metric "
                                         "export check cannot run"),
                             "text": ""})
    else:
        findings.append({"rule": "R4", "file": "rust/src/coordinator/stats.rs",
                         "line": 1, "message": "stats.rs not found", "text": ""})
    findings += r5(design, [(rel, raw) for rel, raw, _ in sources])

    allow_path = os.path.join(root, "tools/slablint/slablint.allow")
    allow_text = ""
    if os.path.isfile(allow_path):
        with open(allow_path, encoding="utf-8") as fh:
            allow_text = fh.read()
    entries = parse_allow(allow_text)
    open_findings, stale = apply_allow(findings, entries)

    for f in open_findings:
        print(f"{f['rule']} {f['file']}:{f['line']} {f['message']}")
        if f["text"]:
            print(f"    {f['text']}")
    for k in stale:
        e = entries[k]
        print(f"STALE slablint.allow:{e['line']} "
              f"`{e['rule']} | {e['file']} | {e['pattern']}` matched nothing "
              "— delete it")
    print(f"slablint(selfcheck): {len(sources)} file(s), "
          f"{len(open_findings)} finding(s) open, "
          f"{len(findings) - len(open_findings)} suppressed, "
          f"{len(stale)} stale allowlist entr(ies)")
    return 0 if not open_findings and not stale else 1


if __name__ == "__main__":
    sys.exit(main())
