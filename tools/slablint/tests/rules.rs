//! Fixture suite: every rule has a known-bad fixture that must flag
//! and a boundary fixture that must stay silent. The same assertions
//! run toolchain-free via `python3 tools/slablint/selfcheck.py
//! --fixtures`, which keeps the Python mirror honest.

use slablint::lexer::Stripped;
use slablint::rules;

fn fixture(name: &str) -> String {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// Heading + definition context the r5 fixtures resolve against.
const DESIGN_FIXTURE: &str = "\
## 1. System inventory

### 1.1 Errata

[[R1]] Panic-freedom on availability-critical paths.
";

#[test]
fn r1_flags_known_bad() {
    let s = Stripped::new(&fixture("r1_bad.rs"));
    let f = rules::r1("rust/src/stream/shard.rs", &s);
    assert_eq!(f.len(), 4, "unwrap, subscript, panic!, expect: {f:#?}");
    assert!(f.iter().all(|x| x.rule == "R1"));
    assert!(f.iter().any(|x| x.message.contains("subscript")));
}

#[test]
fn r1_boundary_is_silent() {
    let s = Stripped::new(&fixture("r1_ok.rs"));
    let f = rules::r1("rust/src/stream/shard.rs", &s);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn r1_out_of_scope_is_silent() {
    let s = Stripped::new(&fixture("r1_bad.rs"));
    let f = rules::r1("rust/src/solver/smo.rs", &s);
    assert!(f.is_empty(), "R1 must only fire on its scoped files");
}

#[test]
fn r2_flags_known_bad() {
    let s = Stripped::new(&fixture("r2_bad.rs"));
    let f = rules::r2("rust/src/stream/fixture.rs", &s);
    assert_eq!(f.len(), 3, "absorb, send, join under live guards: {f:#?}");
    assert!(f.iter().all(|x| x.rule == "R2"));
}

#[test]
fn r2_boundary_is_silent() {
    let s = Stripped::new(&fixture("r2_ok.rs"));
    let f = rules::r2("rust/src/stream/fixture.rs", &s);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn r3_flags_known_bad() {
    let s = Stripped::new(&fixture("r3_bad.rs"));
    let f = rules::r3("rust/src/stream/incremental.rs", &s);
    assert_eq!(f.len(), 3, "clone+collect in hot, vec! in warm loop: {f:#?}");
    assert!(f.iter().all(|x| x.rule == "R3"));
    assert!(
        !f.iter().any(|x| x.text.contains("with_capacity")),
        "set-up allocation in a warm fn must not flag"
    );
}

#[test]
fn r3_boundary_is_silent() {
    let s = Stripped::new(&fixture("r3_ok.rs"));
    let f = rules::r3("rust/src/stream/incremental.rs", &s);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn r3_reports_config_drift() {
    let s = Stripped::new("fn unrelated() {}\n");
    let f = rules::r3("rust/src/stream/incremental.rs", &s);
    assert!(
        f.iter().any(|x| x.message.contains("not found")),
        "a configured fn that disappears must be reported, not skipped"
    );
}

#[test]
fn r4_flags_known_bad() {
    let src = fixture("r4_bad.rs");
    let stats = Stripped::new(&src);
    let sources = vec![("r4_bad.rs".to_string(), Stripped::new(&src))];
    let f = rules::r4("r4_bad.rs", &stats, &sources, "");
    assert_eq!(f.len(), 3, "ghost x2 + silent unsurfaced: {f:#?}");
    assert!(f.iter().any(|x| x.message.contains("never incremented")));
    assert!(f.iter().any(|x| x.message.contains("not surfaced")));
}

#[test]
fn r4_boundary_is_silent() {
    let src = fixture("r4_ok.rs");
    let stats = Stripped::new(&src);
    let sources = vec![("r4_ok.rs".to_string(), Stripped::new(&src))];
    let f = rules::r4("r4_ok.rs", &stats, &sources, "");
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn r4_export_flags_known_bad() {
    let stats = Stripped::new(&fixture("r4_bad.rs"));
    let export = Stripped::new(&fixture("r4_export_bad.rs"));
    let f = rules::r4_export("r4_export_bad.rs", &export, &stats);
    assert_eq!(
        f.len(),
        4,
        "unexported field, bad prefix, duplicate name, lost exporter: {f:#?}"
    );
    assert!(f.iter().all(|x| x.rule == "R4"));
    assert!(f.iter().any(|x| x.message.contains("not exported")));
    assert!(f.iter().any(|x| x.message.contains("prefixed")));
    assert!(f.iter().any(|x| x.message.contains("more than once")));
    assert!(f.iter().any(|x| x.message.contains("json_lines")));
}

#[test]
fn r4_export_boundary_is_silent() {
    let stats = Stripped::new(&fixture("r4_ok.rs"));
    let export = Stripped::new(&fixture("r4_export_ok.rs"));
    let f = rules::r4_export("r4_export_ok.rs", &export, &stats);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn r5_flags_known_bad() {
    let src = fixture("r5_bad.rs");
    let f = rules::r5(DESIGN_FIXTURE, &[("r5_bad.rs".to_string(), src)]);
    assert_eq!(f.len(), 2, "dangling §9 and [[R9]]: {f:#?}");
    assert!(f.iter().all(|x| x.rule == "R5"));
}

#[test]
fn r5_boundary_is_silent() {
    let src = fixture("r5_ok.rs");
    let f = rules::r5(DESIGN_FIXTURE, &[("r5_ok.rs".to_string(), src)]);
    assert!(f.is_empty(), "{f:#?}");
}
