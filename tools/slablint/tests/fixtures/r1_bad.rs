// R1 fixture: scanned under the pseudo-path "rust/src/stream/shard.rs".
// Every construct below must be flagged.

fn worker_step(queue: &Queue, idx: usize) -> f64 {
    let batch = queue.pop().unwrap(); // panic path
    let head = batch.samples[idx]; // variable-index subscript
    if batch.is_empty() {
        panic!("empty batch reached the worker"); // panic path
    }
    head.score().expect("score failed") // panic path
}
