// R4 export fixture (bad), paired with the r4_bad.rs stats struct
// (fields: requests, ghost, silent). Four defects: `ghost` never
// reaches the registry, one metric name is unprefixed, one name is
// registered twice, and the JSON exporter is missing.

pub fn registry(stats: &ServiceStats) -> Vec<Metric> {
    vec![
        counter(
            "slabsvm_requests_total",
            "scoring requests accepted",
            &stats.requests,
        ),
        counter(
            "slabsvm_requests_total",
            "oops, registered under the same name",
            &stats.silent,
        ),
        counter("bad_name", "missing the mandatory prefix", &stats.silent),
    ]
}

pub fn prometheus_text(metrics: &[Metric]) -> String {
    String::new()
}
