// R5 boundary fixture: resolvable references plus the exemptions —
// a paper-section citation (no DESIGN on the line) and bracketed
// text in code rather than comments.

//! Deviation noted in DESIGN.md §1.1; see also lint rule [[R1]].
//! The slab construction follows §3.2 of the paper.

fn noop() {
    let grid = [[1.0, 2.0], [3.0, 4.0]];
    let _ = grid;
}
