// R4 fixture: a ServiceStats with two broken fields. `ghost` is
// never incremented anywhere; `silent` is incremented (below) but
// never surfaced by a summary.

pub struct ServiceStats {
    pub requests: Counter,
    pub ghost: Counter,
    pub silent: Counter,
}

impl ServiceStats {
    pub fn summary(&self) -> String {
        format!("requests={}", self.requests.get())
    }
}

fn elsewhere(stats: &ServiceStats) {
    stats.requests.inc();
    stats.silent.inc();
}
