// R2 boundary fixture: same pseudo-path, zero findings expected.
// Guards die at block close or explicit drop before any barrier;
// statement-temporary guards never register; recv_timeout is the
// sanctioned bounded wait.

fn drain(shard: &Shard) {
    let batch = {
        let mut mail = shard.mail.lock();
        mail.pop()
    }; // guard dead here
    shard.session.absorb(&batch);
}

fn drain_with_drop(shard: &Shard) {
    let mut mail = shard.mail.lock();
    let batch = mail.pop();
    drop(mail);
    shard.session.absorb(&batch);
    shard.tx.send(batch);
}

fn shutdown(pool: &Pool) {
    let handle = pool.worker.lock().take(); // temporary, not a guard
    if let Some(h) = handle {
        h.join();
    }
}

fn batch_wait(w: &Waiter) {
    let guard = w.inner.lock();
    let _ = w.rx.recv_timeout(DURATION); // bounded wait is allowed
    drop(guard);
}
