// R4 export fixture (ok), paired with the r4_ok.rs stats struct
// (fields: requests, absorb_latency): every field is registered under
// a unique slabsvm_-prefixed name and both exposition formats exist.

pub fn registry(stats: &ServiceStats) -> Vec<Metric> {
    vec![
        counter(
            "slabsvm_requests_total",
            "scoring requests accepted",
            &stats.requests,
        ),
        histogram(
            "slabsvm_absorb_latency_us",
            "per-sample absorb latency (microseconds)",
            &stats.absorb_latency,
        ),
    ]
}

pub fn prometheus_text(metrics: &[Metric]) -> String {
    String::new()
}

pub fn json_lines(metrics: &[Metric]) -> String {
    String::new()
}
