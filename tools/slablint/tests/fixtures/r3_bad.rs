// R3 fixture: pseudo-path "rust/src/stream/incremental.rs" (so the
// incremental config applies). `repair` is a hot fn — the clone and
// collect are flagged anywhere in its body; `push` is warm — the
// vec! inside the loop is flagged, the set-up allocation is not.
// Every other configured fn is present as a clean stub so only the
// planted violations fire.

fn repair(&mut self) -> Result<()> {
    let snapshot = self.alpha.clone(); // flagged: alloc in hot fn
    let idx: Vec<usize> = (0..self.len()).collect(); // flagged
    self.apply(&snapshot, &idx)
}

fn push(&mut self, x: &[f64]) -> Result<()> {
    let staged = Vec::with_capacity(x.len()); // set-up alloc: fine
    for v in x {
        let row = vec![*v; self.dim]; // flagged: alloc inside loop
        self.admit(row);
    }
    self.commit(staged)
}

fn bump_alpha(&mut self, i: usize, d: f64) {
    self.mass += d;
}
fn bump_abar(&mut self, i: usize, d: f64) {
    self.mass_bar += d;
}
fn distribute(&mut self, pool: f64) {
    self.mass += pool;
}
fn collect(&mut self, want: f64) -> f64 {
    want
}
fn seed(&mut self, i: usize) {
    self.mass = 1.0;
}
fn replace_slot(&mut self, i: usize) {
    self.dirty = true;
}
fn grow_add(&mut self) {
    self.len += 1;
}
fn margin_of_slot(&self, i: usize) -> f64 {
    self.cache_margin
}
fn recompute_margins(&mut self) {
    self.dirty = false;
}
fn score(&self, x: &[f64]) -> f64 {
    self.cache_margin
}
fn forget(&mut self, id: u64) -> Result<()> {
    Ok(())
}
fn forget_many(&mut self, ids: &[u64]) -> Result<()> {
    for id in ids {
        self.drop_id(*id);
    }
    Ok(())
}
