// R1 boundary fixture: same pseudo-path, zero findings expected.
// Literal subscripts, slice types, strings/comments mentioning the
// tokens, and test-module unwraps are all fine.

fn decode_header(bytes: &[u8]) -> Result<u32, Error> {
    // .unwrap() in a comment is not code
    let magic = bytes.get(..4).ok_or(Error::Truncated)?;
    let b0 = magic[0]; // literal subscript
    let tail: &[u8] = &bytes[..8]; // literal range subscript
    let msg = "never .unwrap() in a decode path"; // token inside a string
    let _ = (b0, tail, msg);
    parse_u32(bytes)
}

fn parse_slice<'a>(buf: &'a [u8], n: usize) -> Option<&'a [u8]> {
    buf.get(..n)
}

fn split_pair(pair: &[f64]) -> f64 {
    // `let [..]` is a destructuring slice pattern, not an index
    if let [c, s] = pair {
        c + s
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v = vec![1, 2, 3];
        let i = 2;
        assert_eq!(v[i], *v.last().unwrap());
    }
}
