// R2 fixture: pseudo-path "rust/src/stream/fixture.rs". The guard
// `mail` is still live at the absorb and at the channel send.

fn drain(shard: &Shard) {
    let mut mail = shard.mail.lock();
    let batch = mail.pop();
    shard.session.absorb(&batch); // flagged: guard live across absorb
    shard.tx.send(batch); // flagged: guard live across send
    drop(mail);
}

fn reap(pool: &Pool) {
    let guard = pool.workers.read();
    for w in guard.iter() {
        w.handle.join(); // flagged: guard live across thread join
    }
}
