// R5 fixture: both references below are broken against the fixture
// DESIGN text used by the test harness (which defines only [[R1]]
// and headings ## 1. and ### 1.1).

//! See DESIGN.md §9 for the missing section.
//! The bound comes from lint rule [[R9]] which is never defined.

fn noop() {}
