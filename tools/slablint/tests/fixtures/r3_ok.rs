// R3 boundary fixture: same pseudo-path, zero findings expected.
// Scratch reuse via clear/extend and mem::take, pushes into pre-grown
// buffers, and allocation in a *non-loop* position of a warm fn are
// all fine. Every configured fn is present so the config-drift check
// stays quiet.

fn repair(&mut self) -> Result<()> {
    self.scratch.clear();
    self.scratch.extend_from_slice(&self.alpha);
    let warm = std::mem::take(&mut self.scratch);
    let out = solve_from(&mut self.window, warm)?;
    self.scratch = std::mem::replace(&mut self.alpha, out);
    Ok(())
}

fn push(&mut self, x: &[f64]) -> Result<()> {
    let staged = Vec::with_capacity(x.len()); // warm fn, outside loops
    for v in x {
        self.buf.push(*v); // .push( is not an allocation token
    }
    self.commit(staged)
}

fn bump_alpha(&mut self, i: usize, d: f64) {
    self.mass += d;
}
fn bump_abar(&mut self, i: usize, d: f64) {
    self.mass_bar += d;
}
fn distribute(&mut self, pool: f64) {
    self.mass += pool;
}
fn collect(&mut self, want: f64) -> f64 {
    // calling a method that *shares a name* with Iterator::collect
    // must not be mistaken for an allocation
    self.collect_inner(want)
}
fn seed(&mut self, i: usize) {
    self.mass = 1.0;
}
fn replace_slot(&mut self, i: usize) {
    self.dirty = true;
}
fn grow_add(&mut self) {
    // index-free clip loop, mirrors the real implementation's shape
    for j in 0..self.len {
        self.mass += self.margin_of_slot(j);
    }
}
fn margin_of_slot(&self, i: usize) -> f64 {
    self.cache_margin
}
fn recompute_margins(&mut self) {
    self.dirty = false;
}
fn score(&self, x: &[f64]) -> f64 {
    self.cache_margin
}
fn forget(&mut self, id: u64) -> Result<()> {
    Ok(())
}
fn forget_many(&mut self, ids: &[u64]) -> Result<()> {
    for id in ids {
        self.drop_id(*id);
    }
    Ok(())
}
