// R4 boundary fixture: every field is incremented and surfaced.

pub struct ServiceStats {
    pub requests: Counter,
    pub absorb_latency: Histogram,
}

impl ServiceStats {
    pub fn summary(&self) -> String {
        format!(
            "requests={} absorb p50={}us",
            self.requests.get(),
            self.absorb_latency.quantile_us(0.5),
        )
    }
}

fn elsewhere(stats: &ServiceStats) {
    stats.requests.inc();
    stats.absorb_latency.record_us(12);
}
