//! The committed allowlist: `slablint.allow`, one entry per line,
//!
//! ```text
//! RULE | path-suffix | line-substring | justification
//! ```
//!
//! `#`-comments and blank lines are skipped. An entry suppresses every
//! finding of `RULE` in a file ending with `path-suffix` whose source
//! line contains `line-substring`. Two failure modes are both errors:
//! a finding with no entry (new violation) and an entry that matched
//! nothing (stale — the violation was fixed, delete the entry). The
//! stale check is what keeps the allowlist a burn-down list instead of
//! a landfill.

use crate::rules::Finding;

#[derive(Debug)]
pub struct Entry {
    pub rule: String,
    pub file: String,
    pub pattern: String,
    pub justification: String,
    pub line: usize, // line in slablint.allow, for stale reporting
}

pub fn parse(text: &str) -> Result<Vec<Entry>, String> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.splitn(4, '|').map(str::trim).collect();
        if parts.len() != 4 || parts.iter().any(|p| p.is_empty()) {
            return Err(format!(
                "slablint.allow:{}: want `RULE | file | pattern | justification`, \
                 got `{line}`",
                i + 1
            ));
        }
        out.push(Entry {
            rule: parts[0].to_string(),
            file: parts[1].to_string(),
            pattern: parts[2].to_string(),
            justification: parts[3].to_string(),
            line: i + 1,
        });
    }
    Ok(out)
}

/// Split findings into (unsuppressed, stale entry indices).
pub fn apply<'a>(
    findings: &'a [Finding],
    entries: &[Entry],
) -> (Vec<&'a Finding>, Vec<usize>) {
    let mut used = vec![false; entries.len()];
    let mut open = Vec::new();
    for f in findings {
        let hit = entries.iter().position(|e| {
            e.rule == f.rule && f.file.ends_with(&e.file) && f.text.contains(&e.pattern)
        });
        match hit {
            Some(idx) => used[idx] = true,
            None => open.push(f),
        }
    }
    let stale = (0..entries.len()).filter(|&i| !used[i]).collect();
    (open, stale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::Finding;

    fn f(rule: &'static str, file: &str, text: &str) -> Finding {
        Finding {
            rule,
            file: file.into(),
            line: 1,
            message: String::new(),
            text: text.into(),
        }
    }

    #[test]
    fn parse_match_and_stale() {
        let entries = parse(
            "# comment\n\
             R1 | stream/manager.rs | spawn shard worker | startup-only\n\
             R3 | solver/smo.rs | gone.pattern | stale entry\n",
        )
        .unwrap();
        assert_eq!(entries.len(), 2);
        let findings = vec![
            f("R1", "rust/src/stream/manager.rs", ".expect(\"spawn shard worker\")"),
            f("R1", "rust/src/stream/manager.rs", "x[i]"),
        ];
        let (open, stale) = apply(&findings, &entries);
        assert_eq!(open.len(), 1, "unmatched finding must stay open");
        assert_eq!(stale, vec![1], "unused entry must be reported stale");
    }

    #[test]
    fn malformed_rejected() {
        assert!(parse("R1 | only | three").is_err());
    }
}
