//! slablint — repo-native static analysis for the slabsvm serving
//! stack.
//!
//! Walks `rust/src`, runs the five lexical rules from [`rules`]
//! (panic-freedom, lock-across-barrier, hot-loop allocation, counter
//! completeness, doc cross-references), filters through the committed
//! `tools/slablint/slablint.allow`, and exits non-zero on any
//! unsuppressed finding or stale allowlist entry. The dynamic
//! counterpart of R2 lives in `slabsvm::sync` behind the `lock-audit`
//! feature; rule text and policy live in DESIGN.md §7.
//!
//! Usage: `cargo run -p slablint [-- --root <repo-root>]`

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use slablint::allowlist;
use slablint::lexer::Stripped;
use slablint::rules::{self, Finding};

fn main() -> ExitCode {
    let root = match repo_root() {
        Some(r) => r,
        None => {
            eprintln!(
                "slablint: cannot locate repo root (want DESIGN.md + rust/src); \
                 pass --root <path>"
            );
            return ExitCode::from(2);
        }
    };
    match run(&root) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("slablint: {e}");
            ExitCode::from(2)
        }
    }
}

fn repo_root() -> Option<PathBuf> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--root" {
            return args.next().map(PathBuf::from);
        }
    }
    // walk up from the cwd, then from the crate dir (cargo run sets
    // cwd to the workspace root already, but be robust to both)
    let starts = [
        std::env::current_dir().ok(),
        Some(PathBuf::from(env!("CARGO_MANIFEST_DIR"))),
    ];
    for start in starts.into_iter().flatten() {
        let mut dir = start.as_path();
        loop {
            if dir.join("DESIGN.md").is_file() && dir.join("rust/src").is_dir() {
                return Some(dir.to_path_buf());
            }
            match dir.parent() {
                Some(p) => dir = p,
                None => break,
            }
        }
    }
    None
}

fn run(root: &Path) -> Result<bool, String> {
    let src_root = root.join("rust/src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    files.sort();

    // (repo-relative path with /, raw source, stripped)
    let mut sources: Vec<(String, String, Stripped)> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let raw = fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let stripped = Stripped::new(&raw);
        sources.push((rel, raw, stripped));
    }
    let design = fs::read_to_string(root.join("DESIGN.md"))
        .map_err(|e| format!("read DESIGN.md: {e}"))?;

    let mut findings: Vec<Finding> = Vec::new();
    for (rel, _, s) in &sources {
        findings.extend(rules::r1(rel, s));
        findings.extend(rules::r2(rel, s));
        findings.extend(rules::r3(rel, s));
    }
    if let Some((rel, _, stats)) =
        sources.iter().find(|(r, _, _)| r.ends_with("coordinator/stats.rs"))
    {
        // stripped, so a counter named only in a CLI comment does not
        // count as "surfaced"
        let surface_extra = sources
            .iter()
            .find(|(r, _, _)| r.ends_with("src/main.rs"))
            .map(|(_, _, s)| s.lines.join("\n"))
            .unwrap_or_default();
        let pairs: Vec<(String, Stripped)> = sources
            .iter()
            .map(|(r, raw, _)| (r.clone(), Stripped::new(raw)))
            .collect();
        findings.extend(rules::r4(rel, stats, &pairs, &surface_extra));
        // export half: every field must also reach the obs registry
        match sources.iter().find(|(r, _, _)| r.ends_with("obs/export.rs")) {
            Some((erel, _, export)) => {
                findings.extend(rules::r4_export(erel, export, stats));
            }
            None => findings.push(Finding {
                rule: "R4",
                file: "rust/src/obs/export.rs".into(),
                line: 1,
                message: "obs/export.rs not found — metric export check \
                          cannot run"
                    .into(),
                text: String::new(),
            }),
        }
    } else {
        findings.push(Finding {
            rule: "R4",
            file: "rust/src/coordinator/stats.rs".into(),
            line: 1,
            message: "stats.rs not found — R4 cannot run".into(),
            text: String::new(),
        });
    }
    let raw_pairs: Vec<(String, String)> = sources
        .iter()
        .map(|(r, raw, _)| (r.clone(), raw.clone()))
        .collect();
    findings.extend(rules::r5(&design, &raw_pairs));

    let allow_path = root.join("tools/slablint/slablint.allow");
    let allow_text = fs::read_to_string(&allow_path).unwrap_or_default();
    let entries = allowlist::parse(&allow_text)?;

    let (open, stale) = allowlist::apply(&findings, &entries);
    for f in &open {
        println!("{} {}:{} {}", f.rule, f.file, f.line, f.message);
        if !f.text.is_empty() {
            println!("    {}", f.text);
        }
    }
    for &i in &stale {
        let e = &entries[i];
        println!(
            "STALE slablint.allow:{} `{} | {} | {}` matched nothing — delete it",
            e.line, e.rule, e.file, e.pattern
        );
    }
    let suppressed = findings.len() - open.len();
    println!(
        "slablint: {} file(s), {} finding(s) open, {} suppressed, {} stale \
         allowlist entr(ies)",
        sources.len(),
        open.len(),
        suppressed,
        stale.len()
    );
    Ok(open.is_empty() && stale.is_empty())
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in rd {
        let entry = entry.map_err(|e| e.to_string())?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
