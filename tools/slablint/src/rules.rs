//! The five slablint rules.
//!
//! Every rule is lexical: it works on [`crate::lexer::Stripped`] lines
//! (comments and literal contents blanked) so tokens inside strings or
//! docs never fire. Rules R1–R3 skip `#[cfg(test)] mod` regions —
//! tests may unwrap and allocate freely.
//!
//! The rules are specified, with rationale and the allowlist policy,
//! in DESIGN.md §7 ("Static & dynamic analysis").

use crate::lexer::Stripped;

/// One lint finding. `text` is the offending source line (raw), used
/// for allowlist substring matching.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize, // 1-based
    pub message: String,
    pub text: String,
}

fn finding(
    rule: &'static str,
    file: &str,
    idx: usize,
    msg: String,
    s: &Stripped,
) -> Finding {
    Finding {
        rule,
        file: file.to_string(),
        line: idx + 1,
        message: msg,
        text: s.raw.get(idx).map(|l| l.trim().to_string()).unwrap_or_default(),
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

// ---------------------------------------------------------------- R1

/// Files where a panic is an availability bug: shard workers, the
/// mailbox/manager plane, snapshot decoding, the whole HTTP front
/// door (a request must never take down a connection thread, let alone
/// the acceptor), and the approx-engine absorb/score path that shard
/// workers call per sample (DESIGN.md §10). See DESIGN.md §7.
pub const R1_SCOPE: &[&str] = &[
    "stream/shard.rs",
    "stream/manager.rs",
    "stream/persist.rs",
    "coordinator/jobs.rs",
    "serve/http.rs",
    "serve/auth.rs",
    "serve/limits.rs",
    "serve/router.rs",
    "serve/server.rs",
    "kernel/featmap.rs",
    "solver/approx.rs",
    "stream/approx.rs",
];

const R1_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    ".unwrap_unchecked(",
];

pub fn in_scope(file: &str, scope: &[&str]) -> bool {
    scope.iter().any(|s| file.ends_with(s))
}

/// R1: no `unwrap`/`expect`/`panic!` and no variable-index `[]`
/// subscripts in the availability-critical paths. Literal subscripts
/// (`b[0]`, `&x[..8]`) are fine — they cannot depend on untrusted
/// lengths the way a computed index can.
pub fn r1(file: &str, s: &Stripped) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_scope(file, R1_SCOPE) {
        return out;
    }
    for (i, line) in s.lines.iter().enumerate() {
        if s.in_test[i] {
            continue;
        }
        for tok in R1_TOKENS {
            if line.contains(tok) {
                out.push(finding(
                    "R1",
                    file,
                    i,
                    format!("panic path `{tok}` in availability-critical file"),
                    s,
                ));
            }
        }
        for f in variable_subscripts(line) {
            out.push(finding(
                "R1",
                file,
                i,
                format!("variable-index subscript `[{f}]` can panic; use .get()"),
                s,
            ));
        }
    }
    out
}

/// Find `expr[idx]` subscripts on one line whose index is not a pure
/// numeric/range literal. Returns the index texts. Only same-line
/// subscripts are detected — rustfmt keeps these on one line.
fn variable_subscripts(line: &str) -> Vec<String> {
    let b: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == '[' {
            // a subscript's `[` follows an identifier char, `)` or `]`;
            // `&[…]` slices, `vec![…]`, attributes `#[…]` do not, and
            // neither does a keyword (`&mut [f64]` is a type, not an
            // index)
            let mut k = i;
            while k > 0 && b[k - 1].is_whitespace() {
                k -= 1;
            }
            let prev = if k > 0 { Some(b[k - 1]) } else { None };
            let mut w = k;
            while w > 0 && is_ident(b[w - 1]) {
                w -= 1;
            }
            let word: String = b[w..k].iter().collect();
            // `let [a, b] = …` is a destructuring slice pattern, not
            // an index expression
            let keyword = matches!(
                word.as_str(),
                "mut" | "ref" | "dyn" | "in" | "as" | "return" | "else"
                    | "match" | "if" | "move" | "impl" | "where" | "let"
            );
            // a lifetime before the bracket (`&'a [u8]`) is a slice
            // type, not an index expression
            let lifetime = w > 0 && b[w - 1] == '\'';
            let is_index = !keyword
                && !lifetime
                && matches!(prev, Some(p) if is_ident(p) || p == ')' || p == ']');
            if is_index {
                let mut depth = 1;
                let mut j = i + 1;
                while j < b.len() && depth > 0 {
                    match b[j] {
                        '[' => depth += 1,
                        ']' => depth -= 1,
                        _ => {}
                    }
                    j += 1;
                }
                if depth == 0 {
                    let idx: String = b[i + 1..j - 1].iter().collect();
                    let literal = !idx.is_empty()
                        && idx.chars().all(|c| {
                            c.is_ascii_digit() || c == '.' || c == '_' || c.is_whitespace()
                        });
                    let rangeish = idx.trim().is_empty(); // `[..]`? caught by literal dots
                    if !literal && !rangeish {
                        out.push(idx.trim().to_string());
                    }
                    i = j;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------- R2

/// Directories whose lock guards must never be held across a blocking
/// barrier (absorb/repair/send/join/…). `src/sync/` is the enforcement
/// layer itself and is exempt.
pub const R2_SCOPE: &[&str] = &["src/stream/", "src/coordinator/"];

/// Calls that block, hand work to another thread, or re-enter the
/// solver. Holding a mutex across any of these is the deadlock /
/// tail-latency shape the tracked-lock runtime also polices.
/// `.join()` is exact (thread join takes no args; `Path::join("x")`
/// does) and `.recv()` is exact (`recv_timeout` is the sanctioned
/// bounded wait in the batcher).
const R2_BARRIERS: &[&str] = &[
    ".absorb(",
    "absorb_one(",
    ".repair(",
    "repair_in_place(",
    ".send(",
    ".recv()",
    ".submit(",
    ".fit(",
    ".join()",
    "write_atomic(",
    ".adopt(",
    "snapshot_all(",
];

/// R2: a `let`-bound lock guard must not be live at a line containing
/// a barrier call. A guard dies when its enclosing block closes or at
/// an explicit `drop(guard)`.
pub fn r2(file: &str, s: &Stripped) -> Vec<Finding> {
    let mut out = Vec::new();
    if !R2_SCOPE.iter().any(|d| file.contains(d)) || file.contains("src/sync/") {
        return out;
    }
    let mut depth = 0i32;
    // (name, depth at binding): dies when depth < binding depth
    let mut guards: Vec<(String, i32)> = Vec::new();
    let mut pending = String::new(); // multi-line let statement
    for (i, line) in s.lines.iter().enumerate() {
        if s.in_test[i] {
            continue;
        }
        // barrier check first: a guard bound on an earlier line is
        // live here regardless of what this line opens or closes
        if !guards.is_empty() {
            for tok in R2_BARRIERS {
                if line.contains(tok) {
                    let held: Vec<&str> =
                        guards.iter().map(|(n, _)| n.as_str()).collect();
                    out.push(finding(
                        "R2",
                        file,
                        i,
                        format!(
                            "barrier `{tok}` while lock guard(s) [{}] are live",
                            held.join(", ")
                        ),
                        s,
                    ));
                }
            }
        }
        for c in line.chars() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    guards.retain(|(_, d)| *d <= depth);
                }
                _ => {}
            }
        }
        // explicit drop releases a guard early
        for g in std::mem::take(&mut guards) {
            let dropped = line.contains(&format!("drop({})", g.0))
                || line.contains(&format!("drop({});", g.0));
            if !dropped {
                guards.push(g);
            }
        }
        // statement accumulation for `let` bindings
        let t = line.trim();
        if pending.is_empty() && t.starts_with("let ") {
            pending = t.to_string();
        } else if !pending.is_empty() {
            pending.push(' ');
            pending.push_str(t);
        }
        if !pending.is_empty() {
            if pending.ends_with(';') {
                if let Some(name) = guard_binding(&pending) {
                    guards.push((name, depth));
                }
                pending.clear();
            } else if pending.contains('{') {
                // `let x = { … }` block initializer — not a guard chain
                pending.clear();
            }
        }
    }
    out
}

/// Does this single `let` statement bind a lock guard? The acquiring
/// call must be the statement's final call so temporaries
/// (`x.lock().take();`) do not count.
fn guard_binding(stmt: &str) -> Option<String> {
    let acquire = [".lock();", ".read();", ".write();"].iter().any(|t| {
        stmt.ends_with(t) || stmt.ends_with(&t.replace(';', ".unwrap();"))
    });
    if !acquire {
        return None;
    }
    let rest = stmt.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String = rest.chars().take_while(|c| is_ident(*c)).collect();
    if name.is_empty() || name == "_" {
        None
    } else {
        Some(name)
    }
}

// ---------------------------------------------------------------- R3

/// Allocation-shaped tokens. `.push(` is deliberately absent: pushes
/// into pre-grown vectors are amortized O(1) and the window buffers
/// rely on them.
const R3_ALLOC: &[&str] = &[
    "Vec::new(",
    "Vec::with_capacity(",
    "vec![",
    ".to_vec(",
    ".clone(",
    // Iterator::collect is nullary (or turbofished); `.collect(` alone
    // would also hit the solver's own `collect(…)` redistribution
    // helper, which moves mass without allocating
    ".collect()",
    ".collect::<",
    "String::new(",
    "format!(",
    ".to_string(",
    "Box::new(",
];

/// Per-file R3 configuration: `hot` functions may not contain an
/// allocation token anywhere; `warm` functions may allocate only
/// outside loop bodies (set-up allocs are fine, per-iteration are
/// not).
pub struct R3Config {
    pub suffix: &'static str,
    pub hot: &'static [&'static str],
    pub warm: &'static [&'static str],
}

pub const R3_CONFIGS: &[R3Config] = &[
    R3Config {
        suffix: "stream/incremental.rs",
        hot: &[
            "bump_alpha",
            "bump_abar",
            "distribute",
            "collect",
            "seed",
            "replace_slot",
            "grow_add",
            "margin_of_slot",
            "recompute_margins",
            "repair",
            "score",
        ],
        warm: &["push", "forget", "forget_many"],
    },
    R3Config {
        suffix: "solver/smo.rs",
        hot: &["select_partner_second_order", "select_partner"],
        warm: &["solve_from"],
    },
    R3Config {
        suffix: "kernel/featmap.rs",
        hot: &[
            "fourier_into",
            "fourier_dot",
            "landmark_into",
            "landmark_dot",
        ],
        warm: &[],
    },
    R3Config {
        suffix: "solver/approx.rs",
        hot: &[
            "push_grown",
            "replace_row",
            "margin_of",
            "pair_step_alpha",
            "pair_step_abar",
        ],
        warm: &["repair", "remove_row", "batch_init"],
    },
    R3Config {
        suffix: "stream/approx.rs",
        hot: &["score"],
        warm: &["push", "forget", "forget_many"],
    },
];

/// R3: no allocation in per-absorb hot loops. See [`R3_CONFIGS`].
pub fn r3(file: &str, s: &Stripped) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(cfg) = R3_CONFIGS.iter().find(|c| file.ends_with(c.suffix)) else {
        return out;
    };
    let missing = |name: &str| Finding {
        rule: "R3",
        file: file.to_string(),
        line: 1,
        message: format!(
            "configured fn `{name}` not found — update R3_CONFIGS \
             (silently skipping it would disable the rule)"
        ),
        text: String::new(),
    };
    for &name in cfg.hot {
        let Some((start, end)) = fn_body(s, name) else {
            out.push(missing(name));
            continue;
        };
        for (i, line) in s.lines.iter().enumerate().take(end + 1).skip(start) {
            for tok in R3_ALLOC {
                if line.contains(tok) {
                    out.push(finding(
                        "R3",
                        file,
                        i,
                        format!("allocation `{tok}` in hot fn `{name}`"),
                        s,
                    ));
                }
            }
        }
    }
    for &name in cfg.warm {
        let Some((start, end)) = fn_body(s, name) else {
            out.push(missing(name));
            continue;
        };
        for (i, tok) in allocs_in_loops(&s.lines[start..=end]) {
            out.push(finding(
                "R3",
                file,
                start + i,
                format!("allocation `{tok}` inside a loop of warm fn `{name}`"),
                s,
            ));
        }
    }
    out
}

/// Locate `fn name(…) { … }`: returns (first body line, last body
/// line) inclusive, 0-based. Skips `#[cfg(test)]` regions.
fn fn_body(s: &Stripped, name: &str) -> Option<(usize, usize)> {
    let pat = format!("fn {name}");
    let mut i = 0;
    while i < s.lines.len() {
        let line = &s.lines[i];
        if !s.in_test[i] {
            if let Some(p) = line.find(&pat) {
                let after = line[p + pat.len()..].chars().next();
                if matches!(after, Some('(') | Some('<')) {
                    // find opening brace, then match to close
                    let mut depth = 0i32;
                    let mut started = false;
                    let start = i;
                    let mut j = i;
                    while j < s.lines.len() {
                        for c in s.lines[j].chars() {
                            match c {
                                '{' => {
                                    depth += 1;
                                    started = true;
                                }
                                '}' => depth -= 1,
                                _ => {}
                            }
                        }
                        if started && depth <= 0 {
                            return Some((start, j));
                        }
                        j += 1;
                    }
                    return None;
                }
            }
        }
        i += 1;
    }
    None
}

/// Scan a fn body for alloc tokens that sit inside a `for`/`while`/
/// `loop` body. Returns (relative line, token). `impl X for Y` lines
/// are not loop headers.
fn allocs_in_loops(body: &[String]) -> Vec<(usize, &'static str)> {
    let mut out = Vec::new();
    let mut stack: Vec<bool> = Vec::new(); // true = loop frame
    let mut pending_loop = false;
    for (i, line) in body.iter().enumerate() {
        let header_ok = !line.contains("impl ");
        let mut word = String::new();
        for c in line.chars().chain(std::iter::once('\n')) {
            if is_ident(c) {
                word.push(c);
                continue;
            }
            if header_ok
                && matches!(word.as_str(), "for" | "while" | "loop")
            {
                pending_loop = true;
            }
            word.clear();
            match c {
                '{' => {
                    stack.push(pending_loop);
                    pending_loop = false;
                }
                '}' => {
                    stack.pop();
                }
                ';' => pending_loop = false,
                _ => {}
            }
        }
        if stack.iter().any(|&l| l) {
            for tok in R3_ALLOC {
                if line.contains(tok) {
                    out.push((i, tok));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------- R4

/// R4: counter completeness. Every `pub` field of `ServiceStats` must
/// (a) be incremented/recorded somewhere in non-test code and (b) be
/// surfaced by `summary()`, `stream_summary()` or the CLI.
///
/// `stats_raw` is stats.rs; `sources` is every (path, Stripped) in the
/// tree (stats.rs included); `surface_extra` is main.rs (CLI) text.
pub fn r4(
    stats_file: &str,
    stats: &Stripped,
    sources: &[(String, Stripped)],
    surface_extra: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let fields = service_stats_fields(stats);
    let surface = {
        let mut s = String::new();
        for name in ["summary", "stream_summary"] {
            if let Some((a, b)) = fn_body(stats, name) {
                for l in &stats.lines[a..=b] {
                    s.push_str(l);
                    s.push('\n');
                }
            }
        }
        s.push_str(surface_extra);
        s
    };
    for (field, line_idx) in fields {
        let inc_pats = [
            format!(".{field}.inc("),
            format!(".{field}.add("),
            format!(".{field}.record"),
        ];
        let incremented = sources.iter().any(|(_, s)| {
            s.lines.iter().enumerate().any(|(i, l)| {
                !s.in_test[i] && inc_pats.iter().any(|p| l.contains(p))
            })
        });
        if !incremented {
            out.push(finding(
                "R4",
                stats_file,
                line_idx,
                format!("ServiceStats field `{field}` is never incremented"),
                stats,
            ));
        }
        let shown = surface.contains(&format!("self.{field}"))
            || surface.contains(&format!(".{field}."));
        if !shown {
            out.push(finding(
                "R4",
                stats_file,
                line_idx,
                format!(
                    "ServiceStats field `{field}` is not surfaced by \
                     summary()/stream_summary()/CLI"
                ),
                stats,
            ));
        }
    }
    out
}

/// R4 (export half): every `pub` field of `ServiceStats` must be
/// folded into the obs metric registry (`rust/src/obs/export.rs`),
/// every registered metric name must be a unique `slabsvm_`-prefixed
/// identifier, and both exposition formats must exist. Complements
/// [`r4`]: that half guarantees a counter is fed and humanly visible,
/// this half guarantees it reaches the machine-readable exports.
///
/// Metric names are recovered positionally: [`crate::lexer::Stripped`]
/// blanks literal contents in place, so a `"` pair in a stripped line
/// brackets the same columns in the raw line. A quoted string inside
/// the registry builder whose content is one bare identifier is a
/// metric name; help strings always contain spaces.
pub fn r4_export(
    export_file: &str,
    export: &Stripped,
    stats: &Stripped,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some((start, end)) = fn_body(export, "registry") else {
        out.push(Finding {
            rule: "R4",
            file: export_file.to_string(),
            line: 1,
            message: "fn registry(…) not found — metric export check \
                      cannot run"
                .into(),
            text: String::new(),
        });
        return out;
    };
    // (a) every stats field reaches the registry builder
    for (field, _) in service_stats_fields(stats) {
        let pat = format!(".{field}");
        let exported = export.lines[start..=end].iter().any(|l| {
            l.match_indices(&pat).any(|(p, m)| {
                !l[p + m.len()..].chars().next().is_some_and(is_ident)
            })
        });
        if !exported {
            out.push(finding(
                "R4",
                export_file,
                start,
                format!(
                    "ServiceStats field `{field}` is not exported by the \
                     obs metric registry"
                ),
                export,
            ));
        }
    }
    // (b) registered names: unique, slabsvm_-prefixed identifiers
    let mut names: Vec<(String, usize)> = Vec::new();
    for i in start..=end {
        let s_chars: Vec<char> = export.lines[i].chars().collect();
        let r_chars: Vec<char> = export
            .raw
            .get(i)
            .map(|l| l.chars().collect())
            .unwrap_or_default();
        let mut j = 0;
        while j < s_chars.len() {
            if s_chars[j] != '"' {
                j += 1;
                continue;
            }
            let mut k = j + 1;
            while k < s_chars.len() && s_chars[k] != '"' {
                k += 1;
            }
            if k < s_chars.len() && k <= r_chars.len() {
                let lit: String = r_chars[j + 1..k].iter().collect();
                if !lit.is_empty() && lit.chars().all(is_ident) {
                    names.push((lit, i));
                }
            }
            j = k + 1;
        }
    }
    let mut seen: Vec<&str> = Vec::new();
    for (name, i) in &names {
        if !name.starts_with("slabsvm_") {
            out.push(finding(
                "R4",
                export_file,
                *i,
                format!("metric name `{name}` is not `slabsvm_`-prefixed"),
                export,
            ));
        }
        if seen.contains(&name.as_str()) {
            out.push(finding(
                "R4",
                export_file,
                *i,
                format!("metric name `{name}` registered more than once"),
                export,
            ));
        } else {
            seen.push(name);
        }
    }
    // (c) both exposition formats exist to render the registry
    for f in ["prometheus_text", "json_lines"] {
        if fn_body(export, f).is_none() {
            out.push(Finding {
                rule: "R4",
                file: export_file.to_string(),
                line: 1,
                message: format!(
                    "exporter fn `{f}` missing from the export layer"
                ),
                text: String::new(),
            });
        }
    }
    out
}

/// `(field name, 0-based line)` for each pub field of ServiceStats.
fn service_stats_fields(s: &Stripped) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let Some(start) = s
        .lines
        .iter()
        .position(|l| l.contains("pub struct ServiceStats"))
    else {
        return out;
    };
    let mut depth = 0i32;
    let mut started = false;
    for (i, line) in s.lines.iter().enumerate().skip(start) {
        if started && depth > 0 {
            let t = line.trim();
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some(colon) = rest.find(':') {
                    let name = rest[..colon].trim();
                    if !name.is_empty() && name.chars().all(is_ident) {
                        out.push((name.to_string(), i));
                    }
                }
            }
        }
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            break;
        }
    }
    out
}

// ---------------------------------------------------------------- R5

/// R5: doc cross-references resolve. Checks two reference kinds:
///
/// * `§X` on any line that also mentions "DESIGN" (so paper-section
///   citations like "§3.2 of the paper" are exempt) must name a real
///   DESIGN.md heading;
/// * `[[sym]]` in DESIGN.md or in Rust comments must have a matching
///   definition line in DESIGN.md (a line starting with `[[sym]]`).
pub fn r5(
    design: &str,
    rs_sources: &[(String, String)], // (path, RAW source)
) -> Vec<Finding> {
    let mut out = Vec::new();
    let headings = design_headings(design);
    let defs = design_definitions(design);

    let mut check_line = |file: &str, idx: usize, line: &str, comment_only: bool| {
        let scan: &str = if comment_only {
            match line.find("//") {
                Some(p) => &line[p..],
                None => return,
            }
        } else {
            line
        };
        if scan.contains("DESIGN") {
            for r in section_refs(scan) {
                if !headings.iter().any(|h| heading_matches(h, &r)) {
                    out.push(Finding {
                        rule: "R5",
                        file: file.to_string(),
                        line: idx + 1,
                        message: format!("§{r} does not match any DESIGN.md heading"),
                        text: line.trim().to_string(),
                    });
                }
            }
        }
        for sym in bracket_refs(scan) {
            let is_def = !comment_only && scan.trim_start().starts_with(&format!("[[{sym}]]"));
            if !is_def && !defs.contains(&sym) {
                out.push(Finding {
                    rule: "R5",
                    file: file.to_string(),
                    line: idx + 1,
                    message: format!("[[{sym}]] has no definition line in DESIGN.md"),
                    text: line.trim().to_string(),
                });
            }
        }
    };

    for (i, line) in design.lines().enumerate() {
        check_line("DESIGN.md", i, line, false);
    }
    for (path, src) in rs_sources {
        for (i, line) in src.lines().enumerate() {
            check_line(path, i, line, true);
        }
    }
    out
}

/// Heading keys: `## 7. Title` → "7", `### 1.1 Title` → "1.1",
/// `### Findings` → "Findings", `### Targeted unlearning …` →
/// "Targeted".
fn design_headings(design: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in design.lines() {
        let t = line.trim_start();
        let rest = if let Some(r) = t.strip_prefix("### ") {
            r
        } else if let Some(r) = t.strip_prefix("## ") {
            r
        } else {
            continue;
        };
        let first: String = rest
            .chars()
            .take_while(|c| !c.is_whitespace())
            .collect();
        out.push(first.trim_end_matches('.').to_string());
    }
    out
}

fn heading_matches(heading: &str, reference: &str) -> bool {
    heading == reference
        || heading.starts_with(&format!("{reference}."))
}

/// Definition lines: DESIGN.md lines starting with `[[sym]]`.
fn design_definitions(design: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in design.lines() {
        let t = line.trim_start().trim_start_matches(['*', '-', ' ']);
        if let Some(rest) = t.strip_prefix("[[") {
            if let Some(end) = rest.find("]]") {
                let sym = &rest[..end];
                if !sym.is_empty() && sym.chars().all(|c| is_ident(c) || c == '-') {
                    out.push(sym.to_string());
                }
            }
        }
    }
    out
}

/// Extract `§<ref>` tokens: digits with optional dots, or a capitalised
/// word (`§Findings`).
fn section_refs(text: &str) -> Vec<String> {
    let b: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == '§' {
            let mut j = i + 1;
            let mut r = String::new();
            while j < b.len() && (b[j].is_alphanumeric() || b[j] == '.') {
                r.push(b[j]);
                j += 1;
            }
            let r = r.trim_end_matches('.').to_string();
            if !r.is_empty() {
                out.push(r);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Extract `[[sym]]` references.
fn bracket_refs(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(p) = rest.find("[[") {
        rest = &rest[p + 2..];
        if let Some(end) = rest.find("]]") {
            let sym = &rest[..end];
            if !sym.is_empty() && sym.chars().all(|c| is_ident(c) || c == '-') {
                out.push(sym.to_string());
            }
            rest = &rest[end + 2..];
        } else {
            break;
        }
    }
    out
}
