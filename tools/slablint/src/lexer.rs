//! Comment- and string-aware stripping of Rust source.
//!
//! The rule engine works on *stripped* lines: comments are blanked and
//! string/char literal contents are replaced by spaces, while every
//! newline is preserved so findings report real line numbers. This is
//! a lexer, not a parser — it only needs to know what is code and what
//! is not, which is exactly the fidelity the lexical rules require.

/// A stripped source file: `lines[i]` is line `i+1` with comments and
/// literal contents blanked; `in_test[i]` marks lines inside a
/// `#[cfg(test)] mod … { … }` region.
pub struct Stripped {
    pub lines: Vec<String>,
    pub in_test: Vec<bool>,
    /// original lines — findings report these (and the allowlist
    /// matches against them, so patterns can cite string contents)
    pub raw: Vec<String>,
}

impl Stripped {
    pub fn new(source: &str) -> Stripped {
        let lines = strip(source);
        let in_test = test_mod_lines(&lines);
        let raw = source.lines().map(str::to_string).collect();
        Stripped { lines, in_test, raw }
    }
}

#[derive(PartialEq)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

/// Blank comments and literal contents, preserving line structure and
/// the quote characters themselves (so `"` still delimits a literal in
/// the output, but its contents can never trip a token match).
pub fn strip(source: &str) -> Vec<String> {
    let b: Vec<char> = source.chars().collect();
    let mut out = String::with_capacity(source.len());
    let mut state = State::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        let next = b.get(i + 1).copied();
        match state {
            State::Code => {
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    out.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b') && is_raw_start(&b, i) {
                    // r"…", r#"…"#, br"…", b"…" — count hashes
                    let mut j = i + 1;
                    if c == 'b' && b.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while b.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&'"') {
                        for _ in i..=j {
                            out.push(' ');
                        }
                        out.pop();
                        out.push('"');
                        state = if c == 'b' && b[i + 1] != 'r' && hashes == 0 {
                            State::Str // b"…" plain byte string
                        } else {
                            State::RawStr(hashes)
                        };
                        i = j + 1;
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else if c == '\'' && is_char_literal(&b, i) {
                    state = State::Char;
                    out.push('\'');
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                if c == '\n' {
                    state = State::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // keep line structure across "…\<newline>…" continuations
                    out.push(' ');
                    out.push(if next == Some('\n') { '\n' } else { ' ' });
                    i += 2;
                } else if c == '"' {
                    state = State::Code;
                    out.push('"');
                    i += 1;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && raw_terminates(&b, i, hashes) {
                    out.push('"');
                    for _ in 0..hashes {
                        out.push(' ');
                    }
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            State::Char => {
                if c == '\\' {
                    out.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    state = State::Code;
                    out.push('\'');
                    i += 1;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.lines().map(str::to_string).collect()
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `r` / `b` starts a raw/byte string only when it is not the tail of
/// an identifier (`for r in …` vs `writer`).
fn is_raw_start(b: &[char], i: usize) -> bool {
    if i > 0 && is_ident(b[i - 1]) {
        return false;
    }
    let mut j = i + 1;
    if b[i] == 'b' {
        if b.get(j) == Some(&'\'') {
            return false; // byte char b'…' — handled as Char? keep simple
        }
        if b.get(j) == Some(&'r') {
            j += 1;
        } else if b.get(j) != Some(&'"') && b.get(j) != Some(&'#') {
            return false;
        }
    }
    while b.get(j) == Some(&'#') {
        j += 1;
    }
    b.get(j) == Some(&'"')
}

fn raw_terminates(b: &[char], i: usize, hashes: u32) -> bool {
    for k in 0..hashes as usize {
        if b.get(i + 1 + k) != Some(&'#') {
            return false;
        }
    }
    true
}

/// `'` starts a char literal (vs a lifetime like `'a` or `'static`).
/// A lifetime is `'` + ident with no closing quote right after.
fn is_char_literal(b: &[char], i: usize) -> bool {
    match b.get(i + 1) {
        Some('\\') => true,
        Some(&c) if is_ident(c) => b.get(i + 2) == Some(&'\''),
        Some(_) => true, // '(' etc — punctuation char literal
        None => false,
    }
}

/// Mark lines belonging to `#[cfg(test)] mod … { … }` regions so the
/// per-path rules skip test code (tests may unwrap freely).
pub fn test_mod_lines(lines: &[String]) -> Vec<bool> {
    let n = lines.len();
    let mut in_test = vec![false; n];
    let mut i = 0;
    while i < n {
        if lines[i].trim_start().starts_with("#[cfg(test)]") {
            let mut j = i + 1;
            while j < n
                && (lines[j].trim().is_empty()
                    || lines[j].trim_start().starts_with("#["))
            {
                j += 1;
            }
            if j < n && lines[j].trim_start().starts_with("mod ") {
                let mut depth = 0i32;
                let mut started = false;
                let mut k = j;
                while k < n {
                    for c in lines[k].chars() {
                        if c == '{' {
                            depth += 1;
                            started = true;
                        } else if c == '}' {
                            depth -= 1;
                        }
                    }
                    in_test[k] = true;
                    if started && depth <= 0 {
                        break;
                    }
                    k += 1;
                }
                in_test[i] = true;
                i = k + 1;
                continue;
            }
        }
        i += 1;
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_blank() {
        let src = "let a = \"x.unwrap()\"; // .unwrap()\nlet b = 1;";
        let lines = strip(src);
        assert!(!lines[0].contains("unwrap"), "{}", lines[0]);
        assert_eq!(lines[1], "let b = 1;");
    }

    #[test]
    fn raw_strings_blank() {
        let src = "let a = r#\"panic!(\"x\")\"#; let c = 2;";
        let lines = strip(src);
        assert!(!lines[0].contains("panic"), "{}", lines[0]);
        assert!(lines[0].contains("let c = 2;"));
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x } // 'a\nlet y = 'c';";
        let lines = strip(src);
        assert!(lines[0].contains("fn f<'a>"));
        assert!(!lines[1].contains('c'), "{}", lines[1]);
    }

    #[test]
    fn test_mod_marked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() {}\n}\nfn c() {}";
        let s = Stripped::new(src);
        assert_eq!(s.in_test, vec![false, true, true, true, true, false]);
    }
}
