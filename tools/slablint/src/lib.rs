//! Library surface of slablint so the integration-test suite (and the
//! fixture runner in `tests/rules.rs`) can drive the lexer and rule
//! engine directly. The binary in `main.rs` is a thin walker over
//! these modules.

pub mod allowlist;
pub mod lexer;
pub mod rules;
