#!/usr/bin/env python3
"""Smoke the slabsvm HTTP front door end to end (CI `serve-smoke` lane).

Spawns the release binary (`slabsvm serve`) on a loopback port, then
drives it with nothing but the Python standard library: liveness,
authenticated scoring (fresh-model version header), auth rejection
(401 missing/unknown token, 403 cross-tenant), stream push, a
pipelined flood against a cap-1 mailbox that must observe 429 +
Retry-After (shed, never a hang), and a tokenless /metrics scrape
whose output must be grammatically valid Prometheus text exposition
carrying every `slabsvm_serve_*` counter with values consistent with
the traffic just sent.

Usage: python3 tools/serve_smoke.py path/to/slabsvm
"""

import json
import socket
import subprocess
import sys
import threading
import time

CHECKS = []


def check(name, cond, detail=""):
    status = "ok" if cond else "FAIL"
    print(f"serve-smoke: {status}: {name}" + (f" ({detail})" if detail else ""))
    CHECKS.append((name, cond))
    if not cond:
        raise SystemExit(f"serve-smoke: FAIL: {name}: {detail}")


def recv_response(sock, buf):
    """Read one content-length-framed response; returns
    (status, headers, body, leftover)."""
    while True:
        idx = buf.find(b"\r\n\r\n")
        if idx >= 0:
            head = buf[:idx].decode()
            lines = head.split("\r\n")
            status = int(lines[0].split(" ")[1])
            headers = {}
            for line in lines[1:]:
                k, _, v = line.partition(":")
                headers[k.strip().lower()] = v.strip()
            clen = int(headers.get("content-length", "0"))
            if len(buf) >= idx + 4 + clen:
                body = buf[idx + 4 : idx + 4 + clen].decode()
                return status, headers, body, buf[idx + 4 + clen :]
        chunk = sock.recv(65536)
        if not chunk:
            raise SystemExit("serve-smoke: FAIL: server closed mid-response")
        buf += chunk


def request(addr, method, path, token=None, body=None):
    """One-shot request on a fresh connection."""
    with socket.create_connection(addr, timeout=30) as s:
        payload = body or ""
        req = f"{method} {path} HTTP/1.1\r\n"
        if token is not None:
            req += f"authorization: Bearer {token}\r\n"
        req += f"content-length: {len(payload)}\r\n"
        req += f"connection: close\r\n\r\n{payload}"
        s.sendall(req.encode())
        status, headers, resp_body, _ = recv_response(s, b"")
        return status, headers, resp_body


def main():
    if len(sys.argv) != 2:
        raise SystemExit(f"usage: {sys.argv[0]} path/to/slabsvm")
    binary = sys.argv[1]

    proc = subprocess.Popen(
        [
            binary, "serve",
            "--addr", "127.0.0.1:0",
            "--auth", "demo=smoketok,other=othertok",
            "--tenants", "demo,other",
            # cap-1 mailbox + warm incremental solver: a pipelined push
            # flood outruns the shard worker, so 429s are observable
            "--shards", "1",
            "--mailbox", "1",
            "--window", "512",
            "--min-train", "16",
            "--train-size", "128",
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    try:
        addr = None
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line:
                break
            line = line.strip()
            print(f"server: {line}")
            if line.startswith("listening on "):
                host, _, port = line.removeprefix("listening on ").rpartition(":")
                addr = (host, int(port))
                break
        check("server prints its bound address", addr is not None)
        # keep draining stdout so the server never blocks on the pipe
        threading.Thread(
            target=lambda: [None for _ in proc.stdout], daemon=True
        ).start()

        # ---- liveness (no auth, rung 1)
        status, _, body = request(addr, "GET", "/healthz")
        check("healthz answers tokenless", status == 200, body)
        check("healthz reports ok", json.loads(body)["ok"] is True, body)

        # ---- authenticated scoring against the startup demo model
        status, headers, body = request(
            addr, "POST", "/v1/score/demo", token="smoketok",
            body='{"queries": [[0.5, 0.5], [20.0, 3.0]]}',
        )
        check("score with valid token", status == 200, body)
        scores = json.loads(body)["scores"]
        check("score returns one score per query", len(scores) == 2, body)
        version = int(headers.get("x-slab-model-version", "0"))
        check("score carries X-Slab-Model-Version >= 1", version >= 1,
              str(headers))

        # ---- auth rejection ladder
        status, headers, body = request(addr, "POST", "/v1/score/demo",
                                        body='{"queries": [[0.0, 0.0]]}')
        check("missing token is 401", status == 401, body)
        check("401 carries WWW-Authenticate",
              "bearer" in headers.get("www-authenticate", "").lower(),
              str(headers))
        status, _, body = request(addr, "POST", "/v1/score/demo",
                                  token="bogus",
                                  body='{"queries": [[0.0, 0.0]]}')
        check("unknown token is 401", status == 401, body)
        status, _, body = request(addr, "POST", "/v1/score/demo",
                                  token="othertok",
                                  body='{"queries": [[0.0, 0.0]]}')
        check("cross-tenant access is 403", status == 403, body)
        auth_failures_sent = 3

        # ---- stream push
        status, _, body = request(addr, "POST", "/v1/streams/demo/push",
                                  token="smoketok",
                                  body='{"x": [20.0, 3.0]}')
        check("push is accepted (202)", status == 202, body)

        # ---- pipelined flood: the cap-1 mailbox must shed with 429,
        #      and every response must arrive (shed, never hang)
        burst = 256
        wire = b""
        for i in range(burst):
            push = f'{{"x": [{20.0 + i * 0.01}, {3.0 - i * 0.01}]}}'
            wire += (
                f"POST /v1/streams/demo/push HTTP/1.1\r\n"
                f"authorization: Bearer smoketok\r\n"
                f"content-length: {len(push)}\r\n\r\n{push}"
            ).encode()
        queued = shed = 0
        with socket.create_connection(addr, timeout=60) as s:
            s.sendall(wire)
            buf = b""
            for _ in range(burst):
                status, headers, body, buf = recv_response(s, buf)
                if status == 202:
                    queued += 1
                elif status == 429:
                    shed += 1
                    check("429 carries Retry-After",
                          "retry-after" in headers, str(headers))
                    check("429 carries X-Slab-Queue-Depth",
                          "x-slab-queue-depth" in headers, str(headers))
                else:
                    check("flood status is 202 or 429", False,
                          f"{status}: {body}")
        check("flood observes 429 shedding", shed > 0,
              f"{queued} queued / {shed} shed over {burst}")
        check("flood still lands samples", queued > 0,
              f"{queued} queued / {shed} shed over {burst}")

        # ---- metrics scrape: tokenless, valid Prometheus grammar,
        #      every serve counter present and consistent
        status, headers, body = request(addr, "GET", "/metrics")
        check("metrics answers tokenless", status == 200)
        check("metrics content type is text exposition",
              headers.get("content-type", "").startswith("text/plain"),
              str(headers))
        values = {}
        bad_lines = []
        for line in body.splitlines():
            if not line:
                continue
            if line.startswith("#"):
                if not (line.startswith("# HELP ") or line.startswith("# TYPE ")):
                    bad_lines.append(line)
                continue
            name, _, value = line.rpartition(" ")
            if not _parses_float(value) or not name.split("{")[0].startswith("slabsvm_"):
                bad_lines.append(line)
                continue
            values[name] = float(value)
        check("metrics body is valid Prometheus text exposition",
              not bad_lines and values, "; ".join(bad_lines[:3]))
        for counter in [
            "slabsvm_serve_accepted_total",
            "slabsvm_serve_shed_total",
            "slabsvm_serve_auth_failed_total",
            "slabsvm_serve_stale_served_total",
            "slabsvm_serve_latency_us_count",
            "slabsvm_serve_latency_us_sum",
        ]:
            check(f"metrics export {counter}", counter in values, counter)
        check("serve_latency histogram has buckets",
              any(k.startswith("slabsvm_serve_latency_us_bucket") for k in values))
        check("accepted counter saw the traffic",
              values["slabsvm_serve_accepted_total"] >= queued + 3,
              str(values["slabsvm_serve_accepted_total"]))
        check("shed counter matches the flood",
              values["slabsvm_serve_shed_total"] >= shed,
              str(values["slabsvm_serve_shed_total"]))
        check("auth-failed counter saw the rejections",
              values["slabsvm_serve_auth_failed_total"] >= auth_failures_sent,
              str(values["slabsvm_serve_auth_failed_total"]))

        passed = sum(1 for _, ok in CHECKS if ok)
        print(f"serve-smoke: PASS ({passed} checks)")
    finally:
        proc.kill()
        proc.wait()


def _parses_float(text):
    try:
        float(text)
        return True
    except ValueError:
        return False


if __name__ == "__main__":
    main()
